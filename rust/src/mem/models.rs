//! The paper's eight memory organizations as concrete [`MemModel`]s.
//!
//! Each model owns its cost composition end to end: how many SRAM macros
//! of which shape, the glue logic, the port semantics, and the
//! *re-stacking scales* ([`MemDesign::area_scale`] and friends) the
//! coordinator uses when it swaps the per-macro cost for a
//! PJRT-evaluated one. Nothing outside this module knows how any
//! organization composes its cost — that is the whole point of the
//! trait seam.

use super::model::{MemModel, ModelEntry};
use super::{MemDesign, MemKind, PortModel};
use crate::sram::{macro_cost, MacroCfg, MacroCost};
use crate::synth::{self, LogicCost};

/// Split `depth` into `banks` equal partitions (cyclic), minimum 4 words.
fn bank_depth(depth: u32, banks: u32) -> u32 {
    depth.div_ceil(banks.max(1)).max(4)
}

/// Stack `n` copies of one macro: areas and leakage add, the *logical*
/// access energies stay per-macro (a logical access touches one macro
/// unless the model's `reads_per_*` say otherwise).
fn stack_n(one: MacroCost, n: u32) -> MacroCost {
    let mut sram = MacroCost::default();
    for _ in 0..n {
        sram = sram.stack(one);
    }
    sram.e_read_pj = one.e_read_pj;
    sram.e_write_pj = one.e_write_pj;
    sram
}

/// Parse `"<R>r<W>w"` (e.g. `"4r2w"`).
fn rw(s: &str) -> Option<(u32, u32)> {
    let (r, rest) = s.split_once('r')?;
    let w = rest.strip_suffix('w')?;
    Some((r.parse().ok()?, w.parse().ok()?))
}

// ---------------------------------------------------------------------
// Banked scratchpads (the paper's red baseline)
// ---------------------------------------------------------------------

/// Array-partitioned banked scratchpad of single-port (1RW) macros —
/// cyclic partitioning, same-bank conflicts serialize (paper baseline).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Banked {
    /// Number of cyclic partitions.
    pub banks: u32,
}

/// Banked scratchpad of dual-port (1R1W) macros.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BankedDualPort {
    /// Number of cyclic partitions.
    pub banks: u32,
}

/// Block-partitioned banked scratchpad (contiguous ranges): the paper's
/// §IV-A cyclic-vs-block axis — stride-1 bursts all hit one bank.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BankedBlock {
    /// Number of block partitions.
    pub banks: u32,
}

/// Shared banked-build: the physical composition is identical for all
/// three banked flavors modulo dual-port scaling and the block flag.
fn build_banked(id: String, depth: u32, width: u32, banks: u32, dual_port: bool, block: bool) -> MemDesign {
    let depth = depth.max(4);
    let banks = banks.max(1);
    let bd = bank_depth(depth, banks);
    let one = macro_cost(MacroCfg { depth: bd, width, read_ports: 1, write_ports: 1 });
    let mut sram = stack_n(one, banks);
    let (area_scale, leak_scale, write_energy_scale) =
        if dual_port { (1.3, 1.25, 1.1) } else { (1.0, 1.0, 1.0) };
    // 1R1W macro: ~1.3× the 1RW area/leakage (second port on the cell).
    sram.area_um2 *= area_scale;
    sram.leak_uw *= leak_scale;
    sram.e_write_pj *= write_energy_scale;
    // Crossbar + arbitration: every one of the (up to `banks`) concurrent
    // requesters needs a banks-to-1 return mux, every bank an input mux,
    // and the arbiter compares all pairs of in-flight bank addresses.
    // This quadratic-ish glue is precisely why array partitioning stops
    // scaling (paper §I: banking "provides memory ports with conflicts" —
    // and resolving them dynamically costs interconnect).
    let lanes = banks * if dual_port { 2 } else { 1 };
    let xbar = synth::mux_tree(banks, width).times(lanes as f32);
    let addr_bits = 32 - depth.leading_zeros().min(31);
    let conflict = synth::conflict_comparators(lanes, addr_bits);
    let logic = xbar.beside(conflict).cost();
    MemDesign {
        id,
        is_amm: false,
        depth,
        width,
        sram,
        logic,
        ports: PortModel::PerBank { banks, reads: 1, writes: 1, shared: !dual_port, block },
        freq_factor: 1.0,
        macros: banks,
        macro_depth: bd,
        macro_ports: (1, 1),
        reads_per_write: 0.0,
        reads_per_read: 1.0,
        area_scale,
        leak_scale,
        write_energy_scale,
    }
}

impl MemModel for Banked {
    fn id(&self) -> String {
        format!("banked{}", self.banks)
    }
    fn describe(&self) -> String {
        format!("cyclic array partitioning, {} single-port (1RW) banks", self.banks)
    }
    fn port_model(&self) -> PortModel {
        PortModel::PerBank { banks: self.banks.max(1), reads: 1, writes: 1, shared: true, block: false }
    }
    fn build(&self, depth: u32, width: u32) -> MemDesign {
        build_banked(self.id(), depth, width, self.banks, false, false)
    }
    fn compat_kind(&self) -> Option<MemKind> {
        Some(MemKind::Banked { banks: self.banks })
    }
    fn boxed_clone(&self) -> Box<dyn MemModel> {
        Box::new(*self)
    }
}

impl MemModel for BankedDualPort {
    fn id(&self) -> String {
        format!("banked2p{}", self.banks)
    }
    fn describe(&self) -> String {
        format!("cyclic array partitioning, {} dual-port (1R1W) banks", self.banks)
    }
    fn port_model(&self) -> PortModel {
        PortModel::PerBank { banks: self.banks.max(1), reads: 1, writes: 1, shared: false, block: false }
    }
    fn build(&self, depth: u32, width: u32) -> MemDesign {
        build_banked(self.id(), depth, width, self.banks, true, false)
    }
    fn compat_kind(&self) -> Option<MemKind> {
        Some(MemKind::BankedDualPort { banks: self.banks })
    }
    fn boxed_clone(&self) -> Box<dyn MemModel> {
        Box::new(*self)
    }
}

impl MemModel for BankedBlock {
    fn id(&self) -> String {
        format!("bankedblk{}", self.banks)
    }
    fn describe(&self) -> String {
        format!("block (contiguous-range) partitioning, {} 1RW banks", self.banks)
    }
    fn port_model(&self) -> PortModel {
        PortModel::PerBank { banks: self.banks.max(1), reads: 1, writes: 1, shared: true, block: true }
    }
    fn build(&self, depth: u32, width: u32) -> MemDesign {
        build_banked(self.id(), depth, width, self.banks, false, true)
    }
    fn compat_kind(&self) -> Option<MemKind> {
        Some(MemKind::BankedBlock { banks: self.banks })
    }
    fn boxed_clone(&self) -> Box<dyn MemModel> {
        Box::new(*self)
    }
}

// ---------------------------------------------------------------------
// Multipumping
// ---------------------------------------------------------------------

/// Multipumping: a single macro internally clocked `factor`× faster,
/// exposing `factor` pseudo-ports while degrading the accelerator's
/// external operating frequency by the same factor (paper §I).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MultiPump {
    /// Internal clock multiple (2 or 4 in practice).
    pub factor: u32,
}

impl MemModel for MultiPump {
    fn id(&self) -> String {
        format!("pump{}", self.factor)
    }
    fn describe(&self) -> String {
        format!("multipumping, {}x internal clock ({} pseudo-ports)", self.factor, self.factor)
    }
    fn port_model(&self) -> PortModel {
        let f = self.factor.max(2);
        PortModel::TruePorts { reads: f, writes: f }
    }
    fn build(&self, depth: u32, width: u32) -> MemDesign {
        let depth = depth.max(4);
        let factor = self.factor.max(2);
        let one = macro_cost(MacroCfg { depth, width, read_ports: 1, write_ports: 1 });
        // fast-clock retiming registers on the port interface
        let iface = synth::register_table(1, width * factor, 1, 1);
        MemDesign {
            id: self.id(),
            is_amm: false,
            depth,
            width,
            sram: one,
            logic: iface.cost(),
            ports: PortModel::TruePorts { reads: factor, writes: factor },
            freq_factor: factor as f32,
            macros: 1,
            macro_depth: depth,
            macro_ports: (1, 1),
            reads_per_write: 0.0,
            reads_per_read: 1.0,
            area_scale: 1.0,
            leak_scale: 1.0,
            write_energy_scale: 1.0,
        }
    }
    fn compat_kind(&self) -> Option<MemKind> {
        Some(MemKind::MultiPump { factor: self.factor })
    }
    fn boxed_clone(&self) -> Box<dyn MemModel> {
        Box::new(*self)
    }
}

// ---------------------------------------------------------------------
// Algorithmic multi-port memories (the blue points)
// ---------------------------------------------------------------------

/// Table-based AMM: Live-Value-Table design (LaForest & Steffan).
/// `read_ports × write_ports` replicated 1R1W banks plus an LVT in flops
/// selecting the most-recently-written replica.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LvtAmm {
    /// True read ports.
    pub read_ports: u32,
    /// True write ports.
    pub write_ports: u32,
}

impl MemModel for LvtAmm {
    fn id(&self) -> String {
        format!("lvt{}r{}w", self.read_ports, self.write_ports)
    }
    fn describe(&self) -> String {
        format!("LVT table-based AMM, {}R{}W (r*w full replicas)", self.read_ports, self.write_ports)
    }
    fn is_amm(&self) -> bool {
        true
    }
    fn port_model(&self) -> PortModel {
        PortModel::TruePorts { reads: self.read_ports.max(1), writes: self.write_ports.max(1) }
    }
    fn build(&self, depth: u32, width: u32) -> MemDesign {
        let depth = depth.max(4);
        let r = self.read_ports.max(1);
        let w = self.write_ports.max(1);
        // LaForest LVT: w×r banks of 1R1W, full depth each; LVT tracks
        // the most-recent writer (log2 w bits per word) in flops.
        let replicas = r * w;
        let one = macro_cost(MacroCfg { depth, width, read_ports: 1, write_ports: 1 });
        let mut sram = stack_n(one, replicas);
        sram.e_write_pj = one.e_write_pj * r as f32; // a write updates its row of r replicas
        let lvt_bits = (32 - (w - 1).leading_zeros()).max(1);
        let table = synth::register_table(depth, lvt_bits, r, w);
        let outmux = synth::mux_tree(w, width).times(r as f32);
        let logic = table.beside(outmux).cost();
        MemDesign {
            id: self.id(),
            is_amm: true,
            depth,
            width,
            sram,
            logic,
            ports: PortModel::TruePorts { reads: r, writes: w },
            freq_factor: 1.0,
            macros: replicas,
            macro_depth: depth,
            macro_ports: (1, 1),
            reads_per_write: 0.0,
            reads_per_read: 1.0,
            area_scale: 1.0,
            leak_scale: 1.0,
            write_energy_scale: r as f32,
        }
    }
    fn compat_kind(&self) -> Option<MemKind> {
        Some(MemKind::LvtAmm { read_ports: self.read_ports, write_ports: self.write_ports })
    }
    fn boxed_clone(&self) -> Box<dyn MemModel> {
        Box::new(*self)
    }
}

/// Non-table XOR-based AMM (HB-NTX-RdWr flow, paper Fig 2): read ports
/// doubled via H-NTX-Rd parity banks, write ports added via B-NTX-Wr
/// read-modify-write parity updates. Ports round up to powers of two.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct XorAmm {
    /// True read ports (power of two in the HB-NTX flow).
    pub read_ports: u32,
    /// True write ports (power of two).
    pub write_ports: u32,
}

impl MemModel for XorAmm {
    fn id(&self) -> String {
        let r = self.read_ports.max(1).next_power_of_two();
        let w = self.write_ports.max(1).next_power_of_two();
        format!("xor{r}r{w}w")
    }
    fn describe(&self) -> String {
        format!(
            "HB-NTX-RdWr hierarchical XOR AMM, {}R{}W (binary parity tree)",
            self.read_ports.max(1).next_power_of_two(),
            self.write_ports.max(1).next_power_of_two()
        )
    }
    fn is_amm(&self) -> bool {
        true
    }
    fn port_model(&self) -> PortModel {
        PortModel::TruePorts {
            reads: self.read_ports.max(1).next_power_of_two(),
            writes: self.write_ports.max(1).next_power_of_two(),
        }
    }
    fn build(&self, depth: u32, width: u32) -> MemDesign {
        let depth = depth.max(4);
        let r = self.read_ports.max(1).next_power_of_two();
        let w = self.write_ports.max(1).next_power_of_two();
        // HB-NTX-RdWr hierarchical composition (paper Fig 2): each port
        // doubling splits the data banks in two and adds *one* reference
        // (parity) layer over the split — a binary tree of parity banks.
        //  · level k adds 2^(k-1) parity banks of depth/2^k ⇒ +0.5×
        //    capacity per level (linear, the scheme's selling point over
        //    the flat LaForest XOR design's W·(R+W−1) full copies);
        //  · data banks: 2^L of depth/2^L; parity banks: 2^L − 1.
        let rd_levels = r.trailing_zeros();
        let wr_levels = w.trailing_zeros();
        let levels = rd_levels + wr_levels;
        let group = 2u32.pow(levels);
        let n_banks = 2 * group - 1; // data + parity tree
        let capacity = depth as f32 * (1.0 + 0.5 * levels as f32);
        let bd = ((capacity / n_banks as f32).ceil() as u32).max(4);
        let one = macro_cost(MacroCfg { depth: bd, width, read_ports: 1, write_ports: 1 });
        let mut sram = stack_n(one, n_banks);
        // A write updates its data bank and one parity bank per level
        // (each via read-modify-write).
        sram.e_write_pj = one.e_write_pj * (1.0 + levels as f32);
        let xor_rd = synth::xor_tree(levels + 1, width).times(r as f32);
        let xor_wr = synth::xor_tree(3, width).times(w as f32 * levels.max(1) as f32);
        let addr_bits = 32 - depth.leading_zeros().min(31);
        let conflict = synth::conflict_comparators(r + w, addr_bits);
        let logic = xor_rd.beside(xor_wr).beside(conflict).cost();
        MemDesign {
            id: self.id(),
            is_amm: true,
            depth,
            width,
            sram,
            logic,
            ports: PortModel::TruePorts { reads: r, writes: w },
            freq_factor: 1.0,
            macros: n_banks,
            macro_depth: bd,
            macro_ports: (1, 1),
            reads_per_write: levels as f32, // parity-chain RMW reads
            // A conflicted read XORs one word per level of its parity
            // chain; average between direct hit (1) and full chain.
            reads_per_read: (1.0 + (levels + 1) as f32) * 0.5,
            area_scale: 1.0,
            leak_scale: 1.0,
            write_energy_scale: 1.0 + levels as f32,
        }
    }
    fn compat_kind(&self) -> Option<MemKind> {
        Some(MemKind::XorAmm { read_ports: self.read_ports, write_ports: self.write_ports })
    }
    fn boxed_clone(&self) -> Box<dyn MemModel> {
        Box::new(*self)
    }
}

/// LaForest flat XOR: `W·(R+W−1)` full-depth 1R1W banks — each write
/// port owns `R + W − 1` banks (R read copies + W−1 parity partners);
/// reads XOR one word from each write lane. The design the hierarchical
/// HB-NTX flow improves on (ablation comparator).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct XorFlat {
    /// True read ports.
    pub read_ports: u32,
    /// True write ports.
    pub write_ports: u32,
}

impl MemModel for XorFlat {
    fn id(&self) -> String {
        format!("xorflat{}r{}w", self.read_ports, self.write_ports)
    }
    fn describe(&self) -> String {
        format!("LaForest flat XOR AMM, {}R{}W (w*(r+w-1) full banks)", self.read_ports, self.write_ports)
    }
    fn is_amm(&self) -> bool {
        true
    }
    fn port_model(&self) -> PortModel {
        PortModel::TruePorts { reads: self.read_ports.max(1), writes: self.write_ports.max(1) }
    }
    fn build(&self, depth: u32, width: u32) -> MemDesign {
        let depth = depth.max(4);
        let r = self.read_ports.max(1);
        let w = self.write_ports.max(1);
        let n_banks = w * (r + w - 1);
        let one = macro_cost(MacroCfg { depth, width, read_ports: 1, write_ports: 1 });
        let mut sram = stack_n(one, n_banks);
        sram.e_write_pj = one.e_write_pj * (r + w - 1) as f32; // update own lane
        let xor_rd = synth::xor_tree(w, width).times(r as f32);
        let addr_bits = 32 - depth.leading_zeros().min(31);
        let conflict = synth::conflict_comparators(r + w, addr_bits);
        let logic = xor_rd.beside(conflict).cost();
        MemDesign {
            id: self.id(),
            is_amm: true,
            depth,
            width,
            sram,
            logic,
            ports: PortModel::TruePorts { reads: r, writes: w },
            freq_factor: 1.0,
            macros: n_banks,
            macro_depth: depth,
            macro_ports: (1, 1),
            reads_per_write: (w - 1) as f32,
            reads_per_read: w as f32,
            area_scale: 1.0,
            leak_scale: 1.0,
            write_energy_scale: (r + w - 1) as f32,
        }
    }
    fn compat_kind(&self) -> Option<MemKind> {
        Some(MemKind::XorFlat { read_ports: self.read_ports, write_ports: self.write_ports })
    }
    fn boxed_clone(&self) -> Box<dyn MemModel> {
        Box::new(*self)
    }
}

/// Circuit-level true multiport macro — the design the paper says has
/// "no inherent EDA support"; costed with the quadratic cell-pitch
/// penalty as the upper-bound comparator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CircuitMp {
    /// True read ports.
    pub read_ports: u32,
    /// True write ports.
    pub write_ports: u32,
}

impl MemModel for CircuitMp {
    fn id(&self) -> String {
        format!("cmp{}r{}w", self.read_ports, self.write_ports)
    }
    fn describe(&self) -> String {
        format!("circuit-level true multiport macro, {}R{}W", self.read_ports, self.write_ports)
    }
    fn port_model(&self) -> PortModel {
        PortModel::TruePorts { reads: self.read_ports, writes: self.write_ports }
    }
    fn build(&self, depth: u32, width: u32) -> MemDesign {
        let depth = depth.max(4);
        let cfg = MacroCfg {
            depth,
            width,
            read_ports: self.read_ports,
            write_ports: self.write_ports,
        };
        let one = macro_cost(cfg);
        MemDesign {
            id: self.id(),
            is_amm: false,
            depth,
            width,
            sram: one,
            logic: LogicCost::default(),
            ports: PortModel::TruePorts { reads: self.read_ports, writes: self.write_ports },
            freq_factor: 1.0,
            macros: 1,
            macro_depth: depth,
            macro_ports: (self.read_ports, self.write_ports),
            reads_per_write: 0.0,
            reads_per_read: 1.0,
            area_scale: 1.0,
            leak_scale: 1.0,
            write_energy_scale: 1.0,
        }
    }
    fn compat_kind(&self) -> Option<MemKind> {
        Some(MemKind::CircuitMp { read_ports: self.read_ports, write_ports: self.write_ports })
    }
    fn boxed_clone(&self) -> Box<dyn MemModel> {
        Box::new(*self)
    }
}

// ---------------------------------------------------------------------
// Built-in registry
// ---------------------------------------------------------------------

fn parse_banked(s: &str) -> Option<Box<dyn MemModel>> {
    let banks = s.strip_prefix("banked")?.parse().ok()?;
    Some(Box::new(Banked { banks }))
}

fn parse_banked_dual(s: &str) -> Option<Box<dyn MemModel>> {
    let banks = s.strip_prefix("banked2p")?.parse().ok()?;
    Some(Box::new(BankedDualPort { banks }))
}

fn parse_banked_block(s: &str) -> Option<Box<dyn MemModel>> {
    let banks = s.strip_prefix("bankedblk")?.parse().ok()?;
    Some(Box::new(BankedBlock { banks }))
}

fn parse_pump(s: &str) -> Option<Box<dyn MemModel>> {
    let factor = s.strip_prefix("pump")?.parse().ok()?;
    Some(Box::new(MultiPump { factor }))
}

fn parse_lvt(s: &str) -> Option<Box<dyn MemModel>> {
    let (read_ports, write_ports) = rw(s.strip_prefix("lvt")?)?;
    Some(Box::new(LvtAmm { read_ports, write_ports }))
}

fn parse_xor(s: &str) -> Option<Box<dyn MemModel>> {
    // "xorflat…" is owned by parse_xor_flat; reject it here so the
    // registry stays order-independent.
    let rest = s.strip_prefix("xor")?;
    if rest.starts_with("flat") {
        return None;
    }
    let (read_ports, write_ports) = rw(rest)?;
    Some(Box::new(XorAmm { read_ports, write_ports }))
}

fn parse_xor_flat(s: &str) -> Option<Box<dyn MemModel>> {
    let (read_ports, write_ports) = rw(s.strip_prefix("xorflat")?)?;
    Some(Box::new(XorFlat { read_ports, write_ports }))
}

fn parse_cmp(s: &str) -> Option<Box<dyn MemModel>> {
    let (read_ports, write_ports) = rw(s.strip_prefix("cmp")?)?;
    Some(Box::new(CircuitMp { read_ports, write_ports }))
}

/// The eight built-in model families.
pub const BUILTIN_MODELS: &[ModelEntry] = &[
    ModelEntry {
        prefix: "banked",
        synopsis: "cyclic array partitioning, single-port (1RW) banks (paper baseline)",
        example: "banked8",
        parse: parse_banked,
    },
    ModelEntry {
        prefix: "banked2p",
        synopsis: "cyclic array partitioning, dual-port (1R1W) banks",
        example: "banked2p4",
        parse: parse_banked_dual,
    },
    ModelEntry {
        prefix: "bankedblk",
        synopsis: "block (contiguous-range) partitioning, 1RW banks (paper SIV-A)",
        example: "bankedblk8",
        parse: parse_banked_block,
    },
    ModelEntry {
        prefix: "pump",
        synopsis: "multipumping: K pseudo-ports at 1/K external clock",
        example: "pump2",
        parse: parse_pump,
    },
    ModelEntry {
        prefix: "lvt",
        synopsis: "LVT table-based AMM (LaForest & Steffan)",
        example: "lvt4r2w",
        parse: parse_lvt,
    },
    ModelEntry {
        prefix: "xor",
        synopsis: "HB-NTX-RdWr hierarchical XOR AMM (paper Fig 2)",
        example: "xor4r2w",
        parse: parse_xor,
    },
    ModelEntry {
        prefix: "xorflat",
        synopsis: "LaForest flat XOR AMM (ablation comparator)",
        example: "xorflat4r2w",
        parse: parse_xor_flat,
    },
    ModelEntry {
        prefix: "cmp",
        synopsis: "circuit-level true multiport macro (quadratic pitch penalty)",
        example: "cmp4r2w",
        parse: parse_cmp,
    },
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::parse_model;

    #[test]
    fn ids_round_trip_through_the_registry() {
        let models: Vec<Box<dyn MemModel>> = vec![
            Box::new(Banked { banks: 8 }),
            Box::new(BankedDualPort { banks: 4 }),
            Box::new(BankedBlock { banks: 8 }),
            Box::new(MultiPump { factor: 2 }),
            Box::new(LvtAmm { read_ports: 2, write_ports: 2 }),
            Box::new(XorAmm { read_ports: 4, write_ports: 2 }),
            Box::new(XorFlat { read_ports: 4, write_ports: 2 }),
            Box::new(CircuitMp { read_ports: 4, write_ports: 4 }),
        ];
        for m in &models {
            let parsed = parse_model(&m.id()).unwrap_or_else(|| panic!("{} unparsed", m.id()));
            assert_eq!(parsed.id(), m.id());
            assert_eq!(parsed.is_amm(), m.is_amm(), "{}", m.id());
            assert_eq!(parsed.port_model(), m.port_model(), "{}", m.id());
        }
    }

    #[test]
    fn build_port_model_matches_trait_port_model() {
        // The design a model builds must enforce exactly the semantics
        // the model advertises.
        for id in [
            "banked8", "banked2p4", "bankedblk8", "pump2", "lvt4r2w", "xor4r2w",
            "xorflat4r2w", "cmp2r2w",
        ] {
            let m = parse_model(id).unwrap();
            let d = m.build(4096, 32);
            assert_eq!(d.ports, m.port_model(), "{id}");
            assert_eq!(d.id, m.id(), "{id}");
            assert_eq!(d.is_amm, m.is_amm(), "{id}");
        }
    }

    #[test]
    fn restacking_scales_reproduce_build_energies() {
        // For every model: rebuilding sram cost from (per-macro cost ×
        // macros × scales) must equal what build() composed. This is the
        // contract the coordinator relies on when it patches in
        // PJRT-evaluated macro costs.
        for id in [
            "banked8", "banked2p4", "bankedblk8", "pump2", "lvt4r2w", "xor4r2w",
            "xorflat4r2w", "cmp4r2w",
        ] {
            let d = parse_model(id).unwrap().build(4096, 32);
            let one = macro_cost(MacroCfg {
                depth: d.macro_depth,
                width: d.width,
                read_ports: d.macro_ports.0,
                write_ports: d.macro_ports.1,
            });
            let m = d.macros as f32;
            let area_err = (d.sram.area_um2 - one.area_um2 * m * d.area_scale).abs();
            assert!(area_err / d.sram.area_um2 < 1e-5, "{id} area");
            let leak_err = (d.sram.leak_uw - one.leak_uw * m * d.leak_scale).abs();
            assert!(leak_err / d.sram.leak_uw < 1e-5, "{id} leak");
            assert!((d.sram.e_read_pj - one.e_read_pj).abs() / d.sram.e_read_pj < 1e-5, "{id} e_read");
            assert!(
                (d.sram.e_write_pj - one.e_write_pj * d.write_energy_scale).abs() / d.sram.e_write_pj < 1e-5,
                "{id} e_write"
            );
        }
    }

    #[test]
    fn xor_parser_does_not_swallow_xorflat() {
        assert_eq!(parse_model("xorflat4r2w").unwrap().id(), "xorflat4r2w");
        assert_eq!(parse_model("xor4r2w").unwrap().id(), "xor4r2w");
    }
}
