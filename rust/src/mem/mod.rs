//! Memory-system models — the paper's §II design space.
//!
//! Two things live here, deliberately separated:
//!
//! 1. **Cost composition** ([`MemKind::build`] → [`MemDesign`]): how many
//!    SRAM macros, how much glue logic, and what access-time / frequency
//!    penalty each organization pays. This folds [`crate::sram`] (CACTI
//!    stand-in) and [`crate::synth`] (Design-Compiler stand-in) exactly
//!    the way the paper folds CACTI + DC tables into Aladdin.
//! 2. **Port arbitration** ([`PortModel`]): the per-cycle conflict
//!    semantics the scheduler consults — banked structures serialize
//!    same-bank conflicts, AMMs provide true conflict-free ports,
//!    multipumping provides conflict-free ports at an external frequency
//!    penalty.
//!
//! Functional (bit-accurate) simulators of the XOR and LVT schemes are in
//! [`functional`]; property tests prove the algorithmic schemes actually
//! implement a coherent multi-port memory before we trust their cost
//! models.

pub mod cache;
pub mod functional;

use crate::sram::{macro_cost, MacroCfg, MacroCost};
use crate::synth::{self, LogicCost};

/// Memory organization being explored (the paper's design axes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MemKind {
    /// Array-partitioned banked scratchpad: `banks` cyclic partitions,
    /// each a single-port (1RW) macro. Conflicting same-bank accesses
    /// serialize — the paper's baseline.
    Banked {
        /// Number of cyclic partitions.
        banks: u32,
    },
    /// Banked scratchpad of dual-port (1R1W) macros: one read and one
    /// write per bank per cycle.
    BankedDualPort {
        /// Number of cyclic partitions.
        banks: u32,
    },
    /// Multipumping: a single macro internally clocked `factor`× faster,
    /// exposing `factor` pseudo-ports while degrading the accelerator's
    /// external operating frequency by the same factor (paper §I).
    MultiPump {
        /// Internal clock multiple (2 or 4 in practice).
        factor: u32,
    },
    /// Table-based AMM: Live-Value-Table design (LaForest & Steffan).
    /// `read_ports × write_ports` replicated 1R1W banks plus an LVT in
    /// flops selecting the most-recently-written replica.
    LvtAmm {
        /// True read ports.
        read_ports: u32,
        /// True write ports.
        write_ports: u32,
    },
    /// Non-table XOR-based AMM (HB-NTX-RdWr flow, paper Fig 2): read
    /// ports doubled via H-NTX-Rd parity banks, write ports added via
    /// B-NTX-Wr read-modify-write parity updates.
    XorAmm {
        /// True read ports (power of two in the HB-NTX flow).
        read_ports: u32,
        /// True write ports (power of two).
        write_ports: u32,
    },
    /// Circuit-level true multiport macro — the design the paper says has
    /// "no inherent EDA support"; costed with the quadratic cell-pitch
    /// penalty as the upper-bound comparator.
    CircuitMp {
        /// True read ports.
        read_ports: u32,
        /// True write ports.
        write_ports: u32,
    },
    /// Flat (non-hierarchical) XOR AMM — LaForest et al.'s original
    /// design: `W·(R+W−1)` full-depth 1R1W banks. The baseline HB-NTX's
    /// hierarchical flow improves on (ablation comparator).
    XorFlat {
        /// True read ports.
        read_ports: u32,
        /// True write ports.
        write_ports: u32,
    },
    /// Block-partitioned banked scratchpad: bank = index / ceil(depth/B)
    /// (contiguous ranges). The paper's §IV-A cyclic-vs-block axis:
    /// block partitioning only parallelizes accesses that are *far
    /// apart*, so stride-1 bursts all hit one bank.
    BankedBlock {
        /// Number of block partitions.
        banks: u32,
    },
}

impl MemKind {
    /// Short id used in CSV output and configs.
    pub fn id(&self) -> String {
        match self {
            MemKind::Banked { banks } => format!("banked{banks}"),
            MemKind::BankedDualPort { banks } => format!("banked2p{banks}"),
            MemKind::MultiPump { factor } => format!("pump{factor}"),
            MemKind::LvtAmm { read_ports, write_ports } => format!("lvt{read_ports}r{write_ports}w"),
            MemKind::XorAmm { read_ports, write_ports } => format!("xor{read_ports}r{write_ports}w"),
            MemKind::CircuitMp { read_ports, write_ports } => format!("cmp{read_ports}r{write_ports}w"),
            MemKind::XorFlat { read_ports, write_ports } => format!("xorflat{read_ports}r{write_ports}w"),
            MemKind::BankedBlock { banks } => format!("bankedblk{banks}"),
        }
    }

    /// Is this one of the paper's AMM organizations (blue points in
    /// Fig 4)?
    pub fn is_amm(&self) -> bool {
        matches!(self, MemKind::LvtAmm { .. } | MemKind::XorAmm { .. } | MemKind::XorFlat { .. })
    }

    /// Parse an id produced by [`MemKind::id`] (used by the config layer).
    pub fn parse(s: &str) -> Option<MemKind> {
        fn rw(s: &str) -> Option<(u32, u32)> {
            let (r, rest) = s.split_once('r')?;
            let w = rest.strip_suffix('w')?;
            Some((r.parse().ok()?, w.parse().ok()?))
        }
        if let Some(rest) = s.strip_prefix("banked2p") {
            return Some(MemKind::BankedDualPort { banks: rest.parse().ok()? });
        }
        if let Some(rest) = s.strip_prefix("bankedblk") {
            return Some(MemKind::BankedBlock { banks: rest.parse().ok()? });
        }
        if let Some(rest) = s.strip_prefix("xorflat") {
            let (r, w) = rw(rest)?;
            return Some(MemKind::XorFlat { read_ports: r, write_ports: w });
        }
        if let Some(rest) = s.strip_prefix("banked") {
            return Some(MemKind::Banked { banks: rest.parse().ok()? });
        }
        if let Some(rest) = s.strip_prefix("pump") {
            return Some(MemKind::MultiPump { factor: rest.parse().ok()? });
        }
        if let Some(rest) = s.strip_prefix("lvt") {
            let (r, w) = rw(rest)?;
            return Some(MemKind::LvtAmm { read_ports: r, write_ports: w });
        }
        if let Some(rest) = s.strip_prefix("xor") {
            let (r, w) = rw(rest)?;
            return Some(MemKind::XorAmm { read_ports: r, write_ports: w });
        }
        if let Some(rest) = s.strip_prefix("cmp") {
            let (r, w) = rw(rest)?;
            return Some(MemKind::CircuitMp { read_ports: r, write_ports: w });
        }
        None
    }

    /// Build the physical design for a logical memory of `depth` words ×
    /// `width` bits.
    pub fn build(&self, depth: u32, width: u32) -> MemDesign {
        let depth = depth.max(4);
        match *self {
            MemKind::Banked { banks } => banked(depth, width, banks, false),
            MemKind::BankedDualPort { banks } => banked(depth, width, banks, true),
            MemKind::MultiPump { factor } => multipump(depth, width, factor),
            MemKind::LvtAmm { read_ports, write_ports } => lvt(depth, width, read_ports, write_ports),
            MemKind::XorAmm { read_ports, write_ports } => xor_hbntx(depth, width, read_ports, write_ports),
            MemKind::CircuitMp { read_ports, write_ports } => circuit_mp(depth, width, read_ports, write_ports),
            MemKind::XorFlat { read_ports, write_ports } => xor_flat(depth, width, read_ports, write_ports),
            MemKind::BankedBlock { banks } => {
                let mut d = banked(depth, width, banks, false);
                d.kind = MemKind::BankedBlock { banks: banks.max(1) };
                if let PortModel::PerBank { block, .. } = &mut d.ports {
                    *block = true;
                }
                d
            }
        }
    }
}

/// Per-cycle port semantics the scheduler enforces.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PortModel {
    /// `banks` partitions (element index mod banks), each with
    /// `reads`/`writes` ports per cycle; same-bank overflow stalls.
    PerBank {
        /// Partition count.
        banks: u32,
        /// Read ports per bank (for 1RW macros, a read consumes the
        /// shared port — modeled as reads=1, writes=1, shared=true).
        reads: u32,
        /// Write ports per bank.
        writes: u32,
        /// True if reads and writes contend for one shared port (1RW).
        shared: bool,
        /// Block (contiguous-range) partitioning instead of cyclic.
        block: bool,
    },
    /// True multi-port: up to `reads` reads + `writes` writes per cycle,
    /// any addresses, no conflicts (AMMs, multipump, circuit MP).
    TruePorts {
        /// Global read ports per cycle.
        reads: u32,
        /// Global write ports per cycle.
        writes: u32,
    },
}

/// A fully-costed memory design.
#[derive(Clone, Debug)]
pub struct MemDesign {
    /// Organization that produced this design.
    pub kind: MemKind,
    /// Logical depth (words).
    pub depth: u32,
    /// Word width (bits).
    pub width: u32,
    /// Summed SRAM macro cost.
    pub sram: MacroCost,
    /// Summed glue-logic cost (XOR trees, LVT, muxes, conflict logic).
    pub logic: LogicCost,
    /// Port semantics for the scheduler.
    pub ports: PortModel,
    /// External-clock degradation factor (1.0 except multipumping, where
    /// the accelerator clock is `factor`× slower — paper §I).
    pub freq_factor: f32,
    /// Number of physical SRAM macros (reporting).
    pub macros: u32,
    /// Depth of each physical macro in words (what the memory compiler
    /// is asked for — the coordinator re-queries cost per macro config).
    pub macro_depth: u32,
    /// Reads internally triggered per logical write (B-NTX-Wr parity
    /// read-modify-write) — inflates write energy.
    pub reads_per_write: f32,
    /// Physical banks read per logical read (H-NTX reads all banks in a
    /// row group) — inflates read energy.
    pub reads_per_read: f32,
}

impl MemDesign {
    /// Total area, µm².
    pub fn area_um2(&self) -> f32 {
        self.sram.area_um2 + self.logic.area_um2
    }
    /// Total leakage, µW.
    pub fn leak_uw(&self) -> f32 {
        self.sram.leak_uw + self.logic.leak_uw
    }
    /// Energy of one logical read, pJ.
    pub fn e_read_pj(&self) -> f32 {
        self.sram.e_read_pj * self.reads_per_read + self.logic.e_access_pj
    }
    /// Energy of one logical write, pJ.
    pub fn e_write_pj(&self) -> f32 {
        self.sram.e_write_pj + self.sram.e_read_pj * self.reads_per_write + self.logic.e_access_pj
    }
    /// Access time of one logical access, ns (macro + glue path).
    pub fn t_access_ns(&self) -> f32 {
        self.sram.t_access_ns + self.logic.delay_ns
    }
}

/// Split `depth` into `banks` equal partitions (cyclic), minimum 4 words.
fn bank_depth(depth: u32, banks: u32) -> u32 {
    depth.div_ceil(banks.max(1)).max(4)
}

fn banked(depth: u32, width: u32, banks: u32, dual_port: bool) -> MemDesign {
    let banks = banks.max(1);
    let bd = bank_depth(depth, banks);
    let cfg = MacroCfg { depth: bd, width, read_ports: 1, write_ports: 1 };
    let one = macro_cost(cfg);
    let mut sram = MacroCost::default();
    for _ in 0..banks {
        sram = sram.stack(one);
    }
    // energies: a logical access touches exactly one bank
    sram.e_read_pj = one.e_read_pj;
    sram.e_write_pj = if dual_port { one.e_write_pj * 1.1 } else { one.e_write_pj };
    if dual_port {
        // 1R1W macro: ~1.3× the 1RW area/leakage (second port on the cell)
        sram.area_um2 *= 1.3;
        sram.leak_uw *= 1.25;
    }
    // Crossbar + arbitration: every one of the (up to `banks`) concurrent
    // requesters needs a banks-to-1 return mux, every bank an input mux,
    // and the arbiter compares all pairs of in-flight bank addresses.
    // This quadratic-ish glue is precisely why array partitioning stops
    // scaling (paper §I: banking "provides memory ports with conflicts" —
    // and resolving them dynamically costs interconnect).
    let lanes = banks * if dual_port { 2 } else { 1 };
    let xbar = synth::mux_tree(banks, width).times(lanes as f32);
    let addr_bits = 32 - depth.leading_zeros().min(31);
    let conflict = synth::conflict_comparators(lanes, addr_bits);
    let logic = xbar.beside(conflict).cost();
    MemDesign {
        kind: if dual_port { MemKind::BankedDualPort { banks } } else { MemKind::Banked { banks } },
        depth,
        width,
        sram,
        logic,
        ports: PortModel::PerBank {
            banks,
            reads: 1,
            writes: 1,
            shared: !dual_port,
            block: false,
        },
        freq_factor: 1.0,
        macros: banks,
        macro_depth: bd,
        reads_per_write: 0.0,
        reads_per_read: 1.0,
    }
}

fn multipump(depth: u32, width: u32, factor: u32) -> MemDesign {
    let factor = factor.max(2);
    let cfg = MacroCfg { depth, width, read_ports: 1, write_ports: 1 };
    let one = macro_cost(cfg);
    // fast-clock retiming registers on the port interface
    let iface = synth::register_table(1, width * factor, 1, 1);
    MemDesign {
        kind: MemKind::MultiPump { factor },
        depth,
        width,
        sram: one,
        logic: iface.cost(),
        ports: PortModel::TruePorts { reads: factor, writes: factor },
        freq_factor: factor as f32,
        macros: 1,
        macro_depth: depth,
        reads_per_write: 0.0,
        reads_per_read: 1.0,
    }
}

fn lvt(depth: u32, width: u32, read_ports: u32, write_ports: u32) -> MemDesign {
    let r = read_ports.max(1);
    let w = write_ports.max(1);
    // LaForest LVT: w×r banks of 1R1W, full depth each; LVT tracks the
    // most-recent writer (log2 w bits per word) in flops.
    let replicas = r * w;
    let one = macro_cost(MacroCfg { depth, width, read_ports: 1, write_ports: 1 });
    let mut sram = MacroCost::default();
    for _ in 0..replicas {
        sram = sram.stack(one);
    }
    sram.e_read_pj = one.e_read_pj; // a read hits one replica (post-LVT mux)
    sram.e_write_pj = one.e_write_pj * r as f32; // a write updates its row of r replicas
    let lvt_bits = (32 - (w - 1).leading_zeros()).max(1);
    let table = synth::register_table(depth, lvt_bits, r, w);
    let outmux = synth::mux_tree(w, width).times(r as f32);
    let logic = table.beside(outmux).cost();
    MemDesign {
        kind: MemKind::LvtAmm { read_ports: r, write_ports: w },
        depth,
        width,
        sram,
        logic,
        ports: PortModel::TruePorts { reads: r, writes: w },
        freq_factor: 1.0,
        macros: replicas,
        macro_depth: depth,
        reads_per_write: 0.0,
        reads_per_read: 1.0,
    }
}

fn xor_hbntx(depth: u32, width: u32, read_ports: u32, write_ports: u32) -> MemDesign {
    let r = read_ports.max(1).next_power_of_two();
    let w = write_ports.max(1).next_power_of_two();
    // HB-NTX-RdWr hierarchical composition (paper Fig 2): each port
    // doubling splits the data banks in two and adds *one* reference
    // (parity) layer over the split — a binary tree of parity banks.
    //  · level k adds 2^(k-1) parity banks of depth/2^k ⇒ +0.5× capacity
    //    per level (linear, the scheme's selling point over the flat
    //    LaForest XOR design's W·(R+W−1) full copies);
    //  · data banks: 2^L of depth/2^L; parity banks: 2^L − 1.
    let rd_levels = r.trailing_zeros();
    let wr_levels = w.trailing_zeros();
    let levels = rd_levels + wr_levels;
    let group = 2u32.pow(levels);
    let n_banks = 2 * group - 1; // data + parity tree
    let capacity = depth as f32 * (1.0 + 0.5 * levels as f32);
    let bd = ((capacity / n_banks as f32).ceil() as u32).max(4);
    let one = macro_cost(MacroCfg { depth: bd, width, read_ports: 1, write_ports: 1 });
    let mut sram = MacroCost::default();
    for _ in 0..n_banks {
        sram = sram.stack(one);
    }
    // A conflicted read XORs one word per level of its parity chain;
    // average between the direct hit (1) and full chain (levels+1).
    sram.e_read_pj = one.e_read_pj;
    // A write updates its data bank and one parity bank per level
    // (each via read-modify-write).
    sram.e_write_pj = one.e_write_pj * (1.0 + levels as f32);
    let xor_rd = synth::xor_tree(levels + 1, width).times(r as f32);
    let xor_wr = synth::xor_tree(3, width).times(w as f32 * levels.max(1) as f32);
    let addr_bits = 32 - depth.leading_zeros().min(31);
    let conflict = synth::conflict_comparators(r + w, addr_bits);
    let logic = xor_rd.beside(xor_wr).beside(conflict).cost();
    MemDesign {
        kind: MemKind::XorAmm { read_ports: r, write_ports: w },
        depth,
        width,
        sram,
        logic,
        ports: PortModel::TruePorts { reads: r, writes: w },
        freq_factor: 1.0,
        macros: n_banks,
        macro_depth: bd,
        reads_per_write: levels as f32, // parity-chain RMW reads
        reads_per_read: (1.0 + (levels + 1) as f32) * 0.5,
    }
}

fn circuit_mp(depth: u32, width: u32, read_ports: u32, write_ports: u32) -> MemDesign {
    let cfg = MacroCfg { depth, width, read_ports, write_ports };
    let one = macro_cost(cfg);
    MemDesign {
        kind: MemKind::CircuitMp { read_ports, write_ports },
        depth,
        width,
        sram: one,
        logic: LogicCost::default(),
        ports: PortModel::TruePorts { reads: read_ports, writes: write_ports },
        freq_factor: 1.0,
        macros: 1,
        macro_depth: depth,
        reads_per_write: 0.0,
        reads_per_read: 1.0,
    }
}

/// LaForest flat XOR: W·(R+W−1) full-depth 1R1W banks — each write port
/// owns (R + W−1) banks (R read copies + W−1 parity partners); reads XOR
/// one word from each write lane. The paper cites this as the design the
/// hierarchical HB-NTX flow improves on.
fn xor_flat(depth: u32, width: u32, read_ports: u32, write_ports: u32) -> MemDesign {
    let r = read_ports.max(1);
    let w = write_ports.max(1);
    let n_banks = w * (r + w - 1);
    let one = macro_cost(MacroCfg { depth, width, read_ports: 1, write_ports: 1 });
    let mut sram = MacroCost::default();
    for _ in 0..n_banks {
        sram = sram.stack(one);
    }
    sram.e_read_pj = one.e_read_pj;
    sram.e_write_pj = one.e_write_pj * (r + w - 1) as f32; // update own lane
    let xor_rd = synth::xor_tree(w, width).times(r as f32);
    let addr_bits = 32 - depth.leading_zeros().min(31);
    let conflict = synth::conflict_comparators(r + w, addr_bits);
    let logic = xor_rd.beside(conflict).cost();
    MemDesign {
        kind: MemKind::XorFlat { read_ports: r, write_ports: w },
        depth,
        width,
        sram,
        logic,
        ports: PortModel::TruePorts { reads: r, writes: w },
        freq_factor: 1.0,
        macros: n_banks,
        macro_depth: depth,
        reads_per_write: (w - 1) as f32,
        reads_per_read: w as f32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_roundtrip() {
        for k in [
            MemKind::Banked { banks: 8 },
            MemKind::BankedDualPort { banks: 4 },
            MemKind::MultiPump { factor: 2 },
            MemKind::LvtAmm { read_ports: 2, write_ports: 2 },
            MemKind::XorAmm { read_ports: 4, write_ports: 2 },
            MemKind::CircuitMp { read_ports: 4, write_ports: 4 },
            MemKind::XorFlat { read_ports: 4, write_ports: 2 },
            MemKind::BankedBlock { banks: 8 },
        ] {
            assert_eq!(MemKind::parse(&k.id()), Some(k), "{}", k.id());
        }
        assert_eq!(MemKind::parse("bogus"), None);
    }

    #[test]
    fn banked_area_grows_with_banks() {
        let d1 = MemKind::Banked { banks: 1 }.build(4096, 32);
        let d8 = MemKind::Banked { banks: 8 }.build(4096, 32);
        let d32 = MemKind::Banked { banks: 32 }.build(4096, 32);
        assert!(d8.area_um2() > d1.area_um2());
        assert!(d32.area_um2() > d8.area_um2());
        // but each bank being smaller, access gets faster
        assert!(d8.t_access_ns() < d1.t_access_ns());
    }

    #[test]
    fn amm_cheaper_than_circuit_multiport_at_high_ports() {
        // The paper's premise: algorithmic beats circuit-level for ≥4 ports.
        let xor = MemKind::XorAmm { read_ports: 4, write_ports: 2 }.build(4096, 32);
        let lvt = MemKind::LvtAmm { read_ports: 4, write_ports: 2 }.build(4096, 32);
        let cmp = MemKind::CircuitMp { read_ports: 4, write_ports: 2 }.build(4096, 32);
        assert!(xor.area_um2() < cmp.area_um2(), "xor {} vs cmp {}", xor.area_um2(), cmp.area_um2());
        assert!(lvt.area_um2() < cmp.area_um2(), "lvt {} vs cmp {}", lvt.area_um2(), cmp.area_um2());
    }

    #[test]
    fn xor_has_lower_area_than_lvt_at_same_ports() {
        // Table-based designs pay the replica array r·w; XOR pays 3^levels
        // of *fractional* banks. At 2R2W: LVT = 4 full copies, XOR = 9
        // quarter banks = 2.25 copies ⇒ XOR smaller on area.
        let xor = MemKind::XorAmm { read_ports: 2, write_ports: 2 }.build(8192, 32);
        let lvt = MemKind::LvtAmm { read_ports: 2, write_ports: 2 }.build(8192, 32);
        assert!(
            xor.sram.area_um2 < lvt.sram.area_um2,
            "xor sram {} vs lvt sram {}",
            xor.sram.area_um2,
            lvt.sram.area_um2
        );
        // …and the paper notes non-table designs have *longer latency*
        // (XOR reconstruct path) vs table-based reads.
    }

    #[test]
    fn multipump_degrades_frequency() {
        let mp = MemKind::MultiPump { factor: 2 }.build(1024, 32);
        assert_eq!(mp.freq_factor, 2.0);
        assert_eq!(mp.ports, PortModel::TruePorts { reads: 2, writes: 2 });
    }

    #[test]
    fn true_ports_for_amms() {
        let d = MemKind::XorAmm { read_ports: 4, write_ports: 2 }.build(1024, 64);
        assert_eq!(d.ports, PortModel::TruePorts { reads: 4, writes: 2 });
        let d = MemKind::LvtAmm { read_ports: 2, write_ports: 1 }.build(1024, 64);
        assert_eq!(d.ports, PortModel::TruePorts { reads: 2, writes: 1 });
    }

    #[test]
    fn xor_write_energy_includes_parity_rmw() {
        let xor = MemKind::XorAmm { read_ports: 2, write_ports: 2 }.build(1024, 32);
        let plain = MemKind::Banked { banks: 1 }.build(1024, 32);
        assert!(xor.e_write_pj() > plain.e_write_pj());
    }

    #[test]
    fn non_pow2_ports_round_up_in_xor() {
        let d = MemKind::XorAmm { read_ports: 3, write_ports: 1 }.build(1024, 32);
        assert_eq!(d.kind, MemKind::XorAmm { read_ports: 4, write_ports: 1 });
    }

    #[test]
    fn hierarchical_xor_beats_flat_xor_on_area() {
        // The HB-NTX claim (paper Fig 2): linear capacity growth vs
        // LaForest's multiplicative replication.
        for (r, w) in [(2u32, 2u32), (4, 2), (4, 4)] {
            let hb = MemKind::XorAmm { read_ports: r, write_ports: w }.build(8192, 32);
            let flat = MemKind::XorFlat { read_ports: r, write_ports: w }.build(8192, 32);
            assert!(
                hb.sram.area_um2 < flat.sram.area_um2,
                "{r}R{w}W: hb {} !< flat {}",
                hb.sram.area_um2,
                flat.sram.area_um2
            );
        }
    }

    #[test]
    fn block_partitioning_sets_port_model_flag() {
        let d = MemKind::BankedBlock { banks: 8 }.build(1024, 32);
        assert!(matches!(d.ports, PortModel::PerBank { block: true, banks: 8, .. }));
        assert_eq!(MemKind::parse("bankedblk8"), Some(MemKind::BankedBlock { banks: 8 }));
        // cost identical to cyclic banking (same macros, same glue)
        let c = MemKind::Banked { banks: 8 }.build(1024, 32);
        assert_eq!(d.area_um2(), c.area_um2());
    }

    #[test]
    fn depth_is_clamped() {
        let d = MemKind::Banked { banks: 16 }.build(8, 32);
        assert!(d.area_um2() > 0.0);
        assert!(d.t_access_ns() > 0.0);
    }
}
