//! Memory-system models — the paper's §II design space.
//!
//! Three things live here, deliberately separated:
//!
//! 1. **The model seam** ([`MemModel`] + [`registry`]): every memory
//!    organization is a trait object that knows its id, its port
//!    semantics and how to build a costed design. The eight paper
//!    organizations are in [`models`]; new schemes register a
//!    [`ModelEntry`] and work everywhere (configs, sweeps, `Explorer`,
//!    reports) without touching any other module.
//! 2. **Cost composition** ([`MemModel::build`] → [`MemDesign`]): how
//!    many SRAM macros, how much glue logic, and what access-time /
//!    frequency penalty each organization pays. This folds
//!    [`crate::sram`] (CACTI stand-in) and [`crate::synth`]
//!    (Design-Compiler stand-in) exactly the way the paper folds
//!    CACTI + DC tables into Aladdin. The design also carries the
//!    *re-stacking scales* the coordinator uses to swap in
//!    PJRT-evaluated macro costs without knowing the organization.
//! 3. **Port arbitration** ([`PortModel`]): the per-cycle conflict
//!    semantics the scheduler consults — banked structures serialize
//!    same-bank conflicts, AMMs provide true conflict-free ports,
//!    multipumping provides conflict-free ports at an external
//!    frequency penalty.
//!
//! [`MemKind`] survives as a thin `Copy` enum that forwards into the
//! trait implementations — the value type configs and examples hold.
//!
//! Functional (bit-accurate) simulators of the XOR and LVT schemes are
//! in [`functional`]; property tests prove the algorithmic schemes
//! actually implement a coherent multi-port memory before we trust
//! their cost models.

pub mod cache;
pub mod functional;
pub mod model;
pub mod models;

pub use model::{parse_model, register_model, registry, MemModel, ModelEntry};

use crate::sram::MacroCost;
use crate::synth::LogicCost;

/// Memory organization being explored (the paper's design axes).
///
/// Compat shim: a `Copy` value type whose methods forward into the
/// corresponding [`MemModel`] implementations in [`models`]. New code
/// (and new organizations) should use the trait + registry directly;
/// this enum only exists so configs and call sites can hold a cheap
/// copyable value for the built-in organizations.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MemKind {
    /// Array-partitioned banked scratchpad: `banks` cyclic partitions,
    /// each a single-port (1RW) macro — the paper's baseline.
    Banked {
        /// Number of cyclic partitions.
        banks: u32,
    },
    /// Banked scratchpad of dual-port (1R1W) macros.
    BankedDualPort {
        /// Number of cyclic partitions.
        banks: u32,
    },
    /// Multipumping: `factor` pseudo-ports at `1/factor` external clock.
    MultiPump {
        /// Internal clock multiple (2 or 4 in practice).
        factor: u32,
    },
    /// Table-based AMM: Live-Value-Table design (LaForest & Steffan).
    LvtAmm {
        /// True read ports.
        read_ports: u32,
        /// True write ports.
        write_ports: u32,
    },
    /// Non-table XOR-based AMM (HB-NTX-RdWr flow, paper Fig 2).
    XorAmm {
        /// True read ports (rounded up to a power of two).
        read_ports: u32,
        /// True write ports (rounded up to a power of two).
        write_ports: u32,
    },
    /// Circuit-level true multiport macro (upper-bound comparator).
    CircuitMp {
        /// True read ports.
        read_ports: u32,
        /// True write ports.
        write_ports: u32,
    },
    /// Flat (non-hierarchical) LaForest XOR AMM (ablation comparator).
    XorFlat {
        /// True read ports.
        read_ports: u32,
        /// True write ports.
        write_ports: u32,
    },
    /// Block-partitioned banked scratchpad (paper §IV-A).
    BankedBlock {
        /// Number of block partitions.
        banks: u32,
    },
}

impl MemKind {
    /// The trait-object view of this organization — the seam every
    /// downstream layer actually consumes.
    pub fn model(&self) -> Box<dyn MemModel> {
        match *self {
            MemKind::Banked { banks } => Box::new(models::Banked { banks }),
            MemKind::BankedDualPort { banks } => Box::new(models::BankedDualPort { banks }),
            MemKind::MultiPump { factor } => Box::new(models::MultiPump { factor }),
            MemKind::LvtAmm { read_ports, write_ports } => {
                Box::new(models::LvtAmm { read_ports, write_ports })
            }
            MemKind::XorAmm { read_ports, write_ports } => {
                Box::new(models::XorAmm { read_ports, write_ports })
            }
            MemKind::CircuitMp { read_ports, write_ports } => {
                Box::new(models::CircuitMp { read_ports, write_ports })
            }
            MemKind::XorFlat { read_ports, write_ports } => {
                Box::new(models::XorFlat { read_ports, write_ports })
            }
            MemKind::BankedBlock { banks } => Box::new(models::BankedBlock { banks }),
        }
    }

    /// Short id used in CSV output and configs (forwards to the model).
    pub fn id(&self) -> String {
        self.model().id()
    }

    /// Is this one of the paper's AMM organizations (blue points in
    /// Fig 4)?
    pub fn is_amm(&self) -> bool {
        self.model().is_amm()
    }

    /// Parse an id produced by [`MemKind::id`]. Delegates to the
    /// registry's single id grammar ([`parse_model`]) and maps back via
    /// [`MemModel::compat_kind`]; registry extensions (which have no
    /// `MemKind`) yield `None` here — hold them as trait objects
    /// instead.
    pub fn parse(s: &str) -> Option<MemKind> {
        parse_model(s)?.compat_kind()
    }

    /// Build the physical design for a logical memory of `depth` words ×
    /// `width` bits (forwards to the model).
    pub fn build(&self, depth: u32, width: u32) -> MemDesign {
        let depth = depth.max(4);
        self.model().build(depth, width)
    }
}

/// Per-cycle port semantics the scheduler enforces.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PortModel {
    /// `banks` partitions (element index mod banks), each with
    /// `reads`/`writes` ports per cycle; same-bank overflow stalls.
    PerBank {
        /// Partition count.
        banks: u32,
        /// Read ports per bank (for 1RW macros, a read consumes the
        /// shared port — modeled as reads=1, writes=1, shared=true).
        reads: u32,
        /// Write ports per bank.
        writes: u32,
        /// True if reads and writes contend for one shared port (1RW).
        shared: bool,
        /// Block (contiguous-range) partitioning instead of cyclic.
        block: bool,
    },
    /// True multi-port: up to `reads` reads + `writes` writes per cycle,
    /// any addresses, no conflicts (AMMs, multipump, circuit MP).
    TruePorts {
        /// Global read ports per cycle.
        reads: u32,
        /// Global write ports per cycle.
        writes: u32,
    },
}

/// A fully-costed memory design.
///
/// Self-describing: it carries the producing model's id, AMM flag, and
/// the cost-composition scales, so downstream layers (scheduler,
/// coordinator, reports) never need to know *which* organization built
/// it — the seam that lets new [`MemModel`]s plug in without touching
/// those layers.
#[derive(Clone, Debug)]
pub struct MemDesign {
    /// Id of the model that produced this design (e.g. `xor4r2w`).
    pub id: String,
    /// Whether the producing model is an algorithmic multi-port design.
    pub is_amm: bool,
    /// Logical depth (words).
    pub depth: u32,
    /// Word width (bits).
    pub width: u32,
    /// Summed SRAM macro cost.
    pub sram: MacroCost,
    /// Summed glue-logic cost (XOR trees, LVT, muxes, conflict logic).
    pub logic: LogicCost,
    /// Port semantics for the scheduler.
    pub ports: PortModel,
    /// External-clock degradation factor (1.0 except multipumping, where
    /// the accelerator clock is `factor`× slower — paper §I).
    pub freq_factor: f32,
    /// Number of physical SRAM macros (reporting).
    pub macros: u32,
    /// Depth of each physical macro in words (what the memory compiler
    /// is asked for — the coordinator re-queries cost per macro config).
    pub macro_depth: u32,
    /// (read, write) ports of each physical macro — 1R1W-as-1RW for all
    /// algorithmic schemes, the true port counts for circuit multiport.
    pub macro_ports: (u32, u32),
    /// Reads internally triggered per logical write (B-NTX-Wr parity
    /// read-modify-write) — inflates write energy.
    pub reads_per_write: f32,
    /// Physical banks read per logical read (H-NTX reads all banks in a
    /// row group) — inflates read energy.
    pub reads_per_read: f32,
    /// Re-stacking: per-macro area multiplier beyond `macros` copies
    /// (e.g. 1.3 for dual-port cell growth).
    pub area_scale: f32,
    /// Re-stacking: per-macro leakage multiplier.
    pub leak_scale: f32,
    /// Re-stacking: logical-write energy in units of one macro write
    /// (e.g. `r` for LVT replica updates).
    pub write_energy_scale: f32,
}

impl MemDesign {
    /// Total area, µm².
    pub fn area_um2(&self) -> f32 {
        self.sram.area_um2 + self.logic.area_um2
    }
    /// Total leakage, µW.
    pub fn leak_uw(&self) -> f32 {
        self.sram.leak_uw + self.logic.leak_uw
    }
    /// Energy of one logical read, pJ.
    pub fn e_read_pj(&self) -> f32 {
        self.sram.e_read_pj * self.reads_per_read + self.logic.e_access_pj
    }
    /// Energy of one logical write, pJ.
    pub fn e_write_pj(&self) -> f32 {
        self.sram.e_write_pj + self.sram.e_read_pj * self.reads_per_write + self.logic.e_access_pj
    }
    /// Access time of one logical access, ns (macro + glue path).
    pub fn t_access_ns(&self) -> f32 {
        self.sram.t_access_ns + self.logic.delay_ns
    }
    /// Rebuild the SRAM cost from a fresh per-macro cost, applying the
    /// same composition `build` used (areas/leakage × macros × scales;
    /// energies per logical access). This is how the coordinator patches
    /// PJRT-evaluated macro costs into a design without knowing which
    /// organization produced it.
    pub fn restack(&mut self, one: MacroCost) {
        let m = self.macros.max(1) as f32;
        self.sram.area_um2 = one.area_um2 * m * self.area_scale;
        self.sram.leak_uw = one.leak_uw * m * self.leak_scale;
        self.sram.e_read_pj = one.e_read_pj;
        self.sram.e_write_pj = one.e_write_pj * self.write_energy_scale;
        self.sram.t_access_ns = one.t_access_ns;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_roundtrip() {
        for k in [
            MemKind::Banked { banks: 8 },
            MemKind::BankedDualPort { banks: 4 },
            MemKind::MultiPump { factor: 2 },
            MemKind::LvtAmm { read_ports: 2, write_ports: 2 },
            MemKind::XorAmm { read_ports: 4, write_ports: 2 },
            MemKind::CircuitMp { read_ports: 4, write_ports: 4 },
            MemKind::XorFlat { read_ports: 4, write_ports: 2 },
            MemKind::BankedBlock { banks: 8 },
        ] {
            assert_eq!(MemKind::parse(&k.id()), Some(k), "{}", k.id());
            // and the registry agrees with the shim
            assert_eq!(parse_model(&k.id()).unwrap().id(), k.id());
        }
        assert_eq!(MemKind::parse("bogus"), None);
    }

    #[test]
    fn banked_area_grows_with_banks() {
        let d1 = MemKind::Banked { banks: 1 }.build(4096, 32);
        let d8 = MemKind::Banked { banks: 8 }.build(4096, 32);
        let d32 = MemKind::Banked { banks: 32 }.build(4096, 32);
        assert!(d8.area_um2() > d1.area_um2());
        assert!(d32.area_um2() > d8.area_um2());
        // but each bank being smaller, access gets faster
        assert!(d8.t_access_ns() < d1.t_access_ns());
    }

    #[test]
    fn amm_cheaper_than_circuit_multiport_at_high_ports() {
        // The paper's premise: algorithmic beats circuit-level for ≥4 ports.
        let xor = MemKind::XorAmm { read_ports: 4, write_ports: 2 }.build(4096, 32);
        let lvt = MemKind::LvtAmm { read_ports: 4, write_ports: 2 }.build(4096, 32);
        let cmp = MemKind::CircuitMp { read_ports: 4, write_ports: 2 }.build(4096, 32);
        assert!(xor.area_um2() < cmp.area_um2(), "xor {} vs cmp {}", xor.area_um2(), cmp.area_um2());
        assert!(lvt.area_um2() < cmp.area_um2(), "lvt {} vs cmp {}", lvt.area_um2(), cmp.area_um2());
    }

    #[test]
    fn xor_has_lower_area_than_lvt_at_same_ports() {
        // Table-based designs pay the replica array r·w; XOR pays 3^levels
        // of *fractional* banks. At 2R2W: LVT = 4 full copies, XOR = 9
        // quarter banks = 2.25 copies ⇒ XOR smaller on area.
        let xor = MemKind::XorAmm { read_ports: 2, write_ports: 2 }.build(8192, 32);
        let lvt = MemKind::LvtAmm { read_ports: 2, write_ports: 2 }.build(8192, 32);
        assert!(
            xor.sram.area_um2 < lvt.sram.area_um2,
            "xor sram {} vs lvt sram {}",
            xor.sram.area_um2,
            lvt.sram.area_um2
        );
        // …and the paper notes non-table designs have *longer latency*
        // (XOR reconstruct path) vs table-based reads.
    }

    #[test]
    fn multipump_degrades_frequency() {
        let mp = MemKind::MultiPump { factor: 2 }.build(1024, 32);
        assert_eq!(mp.freq_factor, 2.0);
        assert_eq!(mp.ports, PortModel::TruePorts { reads: 2, writes: 2 });
    }

    #[test]
    fn true_ports_for_amms() {
        let d = MemKind::XorAmm { read_ports: 4, write_ports: 2 }.build(1024, 64);
        assert_eq!(d.ports, PortModel::TruePorts { reads: 4, writes: 2 });
        let d = MemKind::LvtAmm { read_ports: 2, write_ports: 1 }.build(1024, 64);
        assert_eq!(d.ports, PortModel::TruePorts { reads: 2, writes: 1 });
    }

    #[test]
    fn xor_write_energy_includes_parity_rmw() {
        let xor = MemKind::XorAmm { read_ports: 2, write_ports: 2 }.build(1024, 32);
        let plain = MemKind::Banked { banks: 1 }.build(1024, 32);
        assert!(xor.e_write_pj() > plain.e_write_pj());
    }

    #[test]
    fn non_pow2_ports_round_up_in_xor() {
        let d = MemKind::XorAmm { read_ports: 3, write_ports: 1 }.build(1024, 32);
        assert_eq!(d.id, "xor4r1w");
        assert_eq!(d.ports, PortModel::TruePorts { reads: 4, writes: 1 });
    }

    #[test]
    fn hierarchical_xor_beats_flat_xor_on_area() {
        // The HB-NTX claim (paper Fig 2): linear capacity growth vs
        // LaForest's multiplicative replication.
        for (r, w) in [(2u32, 2u32), (4, 2), (4, 4)] {
            let hb = MemKind::XorAmm { read_ports: r, write_ports: w }.build(8192, 32);
            let flat = MemKind::XorFlat { read_ports: r, write_ports: w }.build(8192, 32);
            assert!(
                hb.sram.area_um2 < flat.sram.area_um2,
                "{r}R{w}W: hb {} !< flat {}",
                hb.sram.area_um2,
                flat.sram.area_um2
            );
        }
    }

    #[test]
    fn block_partitioning_sets_port_model_flag() {
        let d = MemKind::BankedBlock { banks: 8 }.build(1024, 32);
        assert!(matches!(d.ports, PortModel::PerBank { block: true, banks: 8, .. }));
        assert_eq!(MemKind::parse("bankedblk8"), Some(MemKind::BankedBlock { banks: 8 }));
        // cost identical to cyclic banking (same macros, same glue)
        let c = MemKind::Banked { banks: 8 }.build(1024, 32);
        assert_eq!(d.area_um2(), c.area_um2());
    }

    #[test]
    fn depth_is_clamped() {
        let d = MemKind::Banked { banks: 16 }.build(8, 32);
        assert!(d.area_um2() > 0.0);
        assert!(d.t_access_ns() > 0.0);
    }

    #[test]
    fn restack_with_own_macro_cost_is_identity() {
        for id in ["banked8", "banked2p4", "pump2", "lvt4r2w", "xor4r2w", "xorflat4r2w", "cmp4r2w"] {
            let mut d = parse_model(id).unwrap().build(4096, 32);
            let orig = d.sram;
            let one = crate::sram::macro_cost(crate::sram::MacroCfg {
                depth: d.macro_depth,
                width: d.width,
                read_ports: d.macro_ports.0,
                write_ports: d.macro_ports.1,
            });
            d.restack(one);
            let rel = |a: f32, b: f32| (a - b).abs() / b.abs().max(1e-9);
            assert!(rel(d.sram.area_um2, orig.area_um2) < 1e-5, "{id} area");
            assert!(rel(d.sram.e_write_pj, orig.e_write_pj) < 1e-5, "{id} e_write");
            assert!(rel(d.sram.leak_uw, orig.leak_uw) < 1e-5, "{id} leak");
        }
    }
}
