//! Bit-accurate functional simulators of the algorithmic multi-port
//! schemes (paper §II). These exist to *prove* the schemes work — that
//! N reads + M writes per cycle, at arbitrary conflicting addresses,
//! always return/commit the right data — before their cost models are
//! trusted in the DSE. Property tests drive them against a flat
//! reference memory (`rust/tests/amm_props.rs`), and the Pallas
//! `xor_recon` kernel is cross-checked against [`HNtxRd`] in
//! `examples/amm_functional.rs`.

/// A memory that can service `read_ports()` reads and `write_ports()`
/// writes in one cycle, at arbitrary addresses.
pub trait MultiPortMem {
    /// Logical capacity in words.
    fn capacity(&self) -> usize;
    /// True read ports.
    fn read_ports(&self) -> usize;
    /// True write ports.
    fn write_ports(&self) -> usize;
    /// Service one cycle: all reads observe the state *before* this
    /// cycle's writes (read-first semantics, matching the registered
    /// SRAM banks the schemes are built from). Writes commit atomically;
    /// if two write ports target the same address the higher port index
    /// wins (fixed priority, as in the LVT papers).
    fn cycle(&mut self, reads: &[usize], writes: &[(usize, u64)]) -> Vec<u64>;
}

// ---------------------------------------------------------------------
// H-NTX-Rd: 2R1W from two half banks + one parity bank (paper §II-A).
// ---------------------------------------------------------------------

/// H-NTX-Rd: Bank0 stores D0 (even half), Bank1 stores D1 (odd half),
/// Ref stores `D0 ⊕ D1`. Two reads of the *same* bank are serviced by
/// reading the sibling bank and the reference: `Bank1[i] ⊕ Ref[i]`.
pub struct HNtxRd {
    half: usize,
    bank0: Vec<u64>,
    bank1: Vec<u64>,
    refb: Vec<u64>,
}

impl HNtxRd {
    /// Capacity = `2 · half` words, all zero.
    pub fn new(half: usize) -> Self {
        HNtxRd { half, bank0: vec![0; half], bank1: vec![0; half], refb: vec![0; half] }
    }

    /// (bank, offset) of a logical address — cyclic split.
    fn map(&self, addr: usize) -> (usize, usize) {
        (addr % 2, addr / 2)
    }

    /// Read through the recovery path (sibling ⊕ ref) — exposed so tests
    /// can force the XOR reconstruction even without a port conflict.
    pub fn read_via_parity(&self, addr: usize) -> u64 {
        let (bank, off) = self.map(addr);
        if bank == 0 {
            self.bank1[off] ^ self.refb[off]
        } else {
            self.bank0[off] ^ self.refb[off]
        }
    }

    /// Direct-path read.
    pub fn read_direct(&self, addr: usize) -> u64 {
        let (bank, off) = self.map(addr);
        if bank == 0 {
            self.bank0[off]
        } else {
            self.bank1[off]
        }
    }
}

impl MultiPortMem for HNtxRd {
    fn capacity(&self) -> usize {
        self.half * 2
    }
    fn read_ports(&self) -> usize {
        2
    }
    fn write_ports(&self) -> usize {
        1
    }

    fn cycle(&mut self, reads: &[usize], writes: &[(usize, u64)]) -> Vec<u64> {
        assert!(reads.len() <= 2 && writes.len() <= 1);
        let mut out = Vec::with_capacity(reads.len());
        // Port 0 always takes the direct path; port 1 takes the direct
        // path unless it conflicts (same bank) with port 0 — then it
        // reconstructs from the sibling + parity banks.
        for (i, &addr) in reads.iter().enumerate() {
            assert!(addr < self.capacity());
            let conflict = i == 1 && self.map(reads[0]).0 == self.map(addr).0;
            out.push(if conflict { self.read_via_parity(addr) } else { self.read_direct(addr) });
        }
        // Write: update the data bank and the parity bank
        // (Ref = D0 ⊕ D1 must keep holding after the write).
        for &(addr, val) in writes {
            assert!(addr < self.capacity());
            let (bank, off) = self.map(addr);
            if bank == 0 {
                self.refb[off] = val ^ self.bank1[off];
                self.bank0[off] = val;
            } else {
                self.refb[off] = val ^ self.bank0[off];
                self.bank1[off] = val;
            }
        }
        out
    }
}

// ---------------------------------------------------------------------
// B-NTX-Wr: 1R2W from two encoded banks + one parity bank (paper §II-A).
// ---------------------------------------------------------------------

/// B-NTX-Wr: Bank0 stores `D0 ⊕ Ref`, Bank1 stores `D1 ⊕ Ref`. A read of
/// half `h` returns `Bank_h[i] ⊕ Ref[i]`. Two same-half writes resolve by
/// routing the second through the parity bank (paper's conflict case:
/// `T = D1[j] ⊕ Ref[j]; Ref[j] = W1[j] ⊕ D0[j]; D1[j] = Ref[j] ⊕ T`).
pub struct BNtxWr {
    half: usize,
    bank0: Vec<u64>, // stores D0 ⊕ Ref
    bank1: Vec<u64>, // stores D1 ⊕ Ref
    refb: Vec<u64>,
}

impl BNtxWr {
    /// Capacity = `2 · half` words, all zero.
    pub fn new(half: usize) -> Self {
        BNtxWr { half, bank0: vec![0; half], bank1: vec![0; half], refb: vec![0; half] }
    }

    fn map(&self, addr: usize) -> (usize, usize) {
        (addr % 2, addr / 2)
    }

    /// Decode the logical value at `addr` (read path).
    pub fn decode(&self, addr: usize) -> u64 {
        let (bank, off) = self.map(addr);
        if bank == 0 {
            self.bank0[off] ^ self.refb[off]
        } else {
            self.bank1[off] ^ self.refb[off]
        }
    }

    /// Commit one write through the "own bank" path: `D = W ⊕ Ref`.
    fn write_direct(&mut self, addr: usize, val: u64) {
        let (bank, off) = self.map(addr);
        let enc = val ^ self.refb[off];
        if bank == 0 {
            self.bank0[off] = enc;
        } else {
            self.bank1[off] = enc;
        }
    }

    /// Commit one write through the parity path (conflict case): adjust
    /// `Ref` so the encoded sibling word decodes unchanged while `addr`
    /// decodes to `val`.
    fn write_via_parity(&mut self, addr: usize, val: u64) {
        let (bank, off) = self.map(addr);
        if bank == 0 {
            let sib = self.bank1[off] ^ self.refb[off]; // current D1
            self.refb[off] = val ^ self.bank0[off];
            self.bank1[off] = sib ^ self.refb[off];
        } else {
            let sib = self.bank0[off] ^ self.refb[off]; // current D0
            self.refb[off] = val ^ self.bank1[off];
            self.bank0[off] = sib ^ self.refb[off];
        }
    }
}

impl MultiPortMem for BNtxWr {
    fn capacity(&self) -> usize {
        self.half * 2
    }
    fn read_ports(&self) -> usize {
        1
    }
    fn write_ports(&self) -> usize {
        2
    }

    fn cycle(&mut self, reads: &[usize], writes: &[(usize, u64)]) -> Vec<u64> {
        assert!(reads.len() <= 1 && writes.len() <= 2);
        let out: Vec<u64> = reads.iter().map(|&a| self.decode(a)).collect();
        match writes {
            [] => {}
            [(a, v)] => self.write_direct(*a, *v),
            [(a0, v0), (a1, v1)] => {
                if a0 == a1 {
                    // same address: port 1 wins (fixed priority)
                    self.write_direct(*a1, *v1);
                } else {
                    let same_bank = self.map(*a0).0 == self.map(*a1).0;
                    // Same offset row would make the parity trick collide
                    // on Ref[off]; hardware resolves it by sequencing the
                    // two RMWs — functionally: apply in port order.
                    if same_bank && self.map(*a0).1 == self.map(*a1).1 {
                        self.write_direct(*a0, *v0);
                        self.write_direct(*a1, *v1);
                    } else if same_bank {
                        self.write_direct(*a0, *v0);
                        self.write_via_parity(*a1, *v1);
                    } else {
                        self.write_direct(*a0, *v0);
                        self.write_direct(*a1, *v1);
                    }
                }
            }
            _ => unreachable!(),
        }
        out
    }
}

// ---------------------------------------------------------------------
// LVT: mR nW via replicated banks + live-value table (paper §II-B).
// ---------------------------------------------------------------------

/// Live-Value-Table AMM: `w` write groups × `r` read replicas of a plain
/// memory; the LVT records, per word, which write group last wrote it;
/// each read port consults the LVT and muxes the right replica.
pub struct LvtAmm {
    capacity: usize,
    r: usize,
    w: usize,
    /// `banks[wg][rp]` — replica for (write group, read port).
    banks: Vec<Vec<Vec<u64>>>,
    lvt: Vec<u8>,
}

impl LvtAmm {
    /// Build an `r`-read, `w`-write LVT memory of `capacity` words.
    pub fn new(capacity: usize, r: usize, w: usize) -> Self {
        assert!(w <= u8::MAX as usize);
        LvtAmm {
            capacity,
            r,
            w,
            banks: vec![vec![vec![0; capacity]; r]; w],
            lvt: vec![0; capacity],
        }
    }
}

impl MultiPortMem for LvtAmm {
    fn capacity(&self) -> usize {
        self.capacity
    }
    fn read_ports(&self) -> usize {
        self.r
    }
    fn write_ports(&self) -> usize {
        self.w
    }

    fn cycle(&mut self, reads: &[usize], writes: &[(usize, u64)]) -> Vec<u64> {
        assert!(reads.len() <= self.r && writes.len() <= self.w);
        let out = reads
            .iter()
            .enumerate()
            .map(|(port, &addr)| {
                let wg = self.lvt[addr] as usize;
                self.banks[wg][port][addr]
            })
            .collect();
        for (wport, &(addr, val)) in writes.iter().enumerate() {
            // Each write port owns a bank row: update all r replicas and
            // claim the word in the LVT. Same-address conflicts resolve
            // by port order (the later port's LVT update wins).
            for rp in 0..self.r {
                self.banks[wport][rp][addr] = val;
            }
            self.lvt[addr] = wport as u8;
        }
        out
    }
}

// ---------------------------------------------------------------------
// HB-NTX-RdWr: recursive composition to nR mW (paper Fig 2).
// ---------------------------------------------------------------------

/// HB-NTX-RdWr built as the paper describes the 2R2W flow: a write layer
/// of B-NTX parity banks over a read layer of H-NTX parity groups. For
/// the functional model we compose generically: `r` reads are served by
/// H-NTX-style reconstruct across read-parity copies; `w` writes are
/// sequenced through B-NTX-style parity RMW. Functionally this must
/// equal a flat memory with `r` reads + `w` writes per cycle, which is
/// exactly what the property tests assert.
pub struct HbNtxRdWr {
    capacity: usize,
    r: usize,
    w: usize,
    /// Ground-truth state maintained through XOR-bank pairs: we keep the
    /// bank0/bank1/ref triple per write lane to preserve the scheme's
    /// data layout (and verify parity invariants), with lane selection by
    /// address interleave.
    lanes: Vec<BNtxWr>,
}

impl HbNtxRdWr {
    /// `r`-read / `w`-write memory of `capacity` words (`w` even lanes).
    pub fn new(capacity: usize, r: usize, w: usize) -> Self {
        let lanes_n = (w.max(2) / 2).max(1);
        let lane_cap = capacity.div_ceil(lanes_n);
        let lane_cap = lane_cap + (lane_cap & 1); // even (two halves)
        HbNtxRdWr {
            capacity,
            r,
            w,
            lanes: (0..lanes_n).map(|_| BNtxWr::new(lane_cap / 2)).collect(),
        }
    }

    fn map(&self, addr: usize) -> (usize, usize) {
        (addr % self.lanes.len(), addr / self.lanes.len())
    }
}

impl MultiPortMem for HbNtxRdWr {
    fn capacity(&self) -> usize {
        self.capacity
    }
    fn read_ports(&self) -> usize {
        self.r
    }
    fn write_ports(&self) -> usize {
        self.w
    }

    fn cycle(&mut self, reads: &[usize], writes: &[(usize, u64)]) -> Vec<u64> {
        assert!(reads.len() <= self.r && writes.len() <= self.w);
        // Reads: every port decodes through its lane's parity network
        // (reads in H-NTX touch all banks of the group — reflected in the
        // cost model's `reads_per_read`).
        let out = reads
            .iter()
            .map(|&addr| {
                let (lane, off) = self.map(addr);
                self.lanes[lane].decode(off)
            })
            .collect();
        // Writes: distribute to lanes; ≤2 same-lane writes go through the
        // lane's 2W parity protocol; >2 would violate the configured port
        // count (asserted).
        let mut per_lane: Vec<Vec<(usize, u64)>> = vec![Vec::new(); self.lanes.len()];
        for &(addr, val) in writes {
            let (lane, off) = self.map(addr);
            per_lane[lane].push((off, val));
        }
        for (lane, ws) in per_lane.into_iter().enumerate() {
            assert!(ws.len() <= 2, "lane over-subscribed: the scheduler must respect write_ports");
            self.lanes[lane].cycle(&[], &ws);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hntx_conflicting_reads_reconstruct() {
        let mut m = HNtxRd::new(8);
        for a in 0..16 {
            m.cycle(&[], &[(a, (a * 11 + 3) as u64)]);
        }
        // Both reads hit bank 0 (even addresses) — port 1 must XOR-recover.
        let out = m.cycle(&[4, 10], &[]);
        assert_eq!(out, vec![4 * 11 + 3, 10 * 11 + 3]);
        // And the parity path itself returns the right value everywhere.
        for a in 0..16 {
            assert_eq!(m.read_via_parity(a), (a * 11 + 3) as u64);
            assert_eq!(m.read_direct(a), (a * 11 + 3) as u64);
        }
    }

    #[test]
    fn bntx_conflicting_writes_preserve_sibling() {
        let mut m = BNtxWr::new(8);
        m.cycle(&[], &[(2, 100), (4, 200)]); // same bank (even), diff offsets
        assert_eq!(m.decode(2), 100);
        assert_eq!(m.decode(4), 200);
        // the odd half must still read 0
        assert_eq!(m.decode(3), 0);
    }

    #[test]
    fn bntx_same_address_port1_wins() {
        let mut m = BNtxWr::new(4);
        m.cycle(&[], &[(5, 1), (5, 2)]);
        assert_eq!(m.decode(5), 2);
    }

    #[test]
    fn lvt_read_sees_latest_writer() {
        let mut m = LvtAmm::new(16, 2, 2);
        m.cycle(&[], &[(3, 7), (9, 8)]);
        let out = m.cycle(&[3, 9], &[(3, 99), (3, 100)]);
        // reads see pre-cycle state
        assert_eq!(out, vec![7, 8]);
        let out = m.cycle(&[3, 3], &[]);
        assert_eq!(out, vec![100, 100]); // port-1 write won
    }

    #[test]
    fn hbntx_full_port_cycle() {
        let mut m = HbNtxRdWr::new(32, 2, 2);
        m.cycle(&[], &[(0, 10), (1, 11)]);
        m.cycle(&[], &[(2, 12), (3, 13)]);
        let out = m.cycle(&[0, 3], &[(0, 99), (2, 98)]);
        assert_eq!(out, vec![10, 13]);
        let out = m.cycle(&[0, 2], &[]);
        assert_eq!(out, vec![99, 98]);
    }

    #[test]
    fn schemes_report_their_ports() {
        assert_eq!(HNtxRd::new(4).read_ports(), 2);
        assert_eq!(BNtxWr::new(4).write_ports(), 2);
        assert_eq!(LvtAmm::new(8, 4, 3).read_ports(), 4);
        assert_eq!(HbNtxRdWr::new(8, 4, 4).write_ports(), 4);
    }
}
