//! The persistent simulation-result store: `sim-store/v1` append-only
//! JSONL.
//!
//! Scheduling is deterministic for a given [`super::Key`] within one
//! scoring context, so a simulation result is an **artifact**, not a
//! per-run side effect: one flat JSON object per line, one line per
//! simulated `(fingerprint, key)` pair. A store written by one campaign
//! warms every later campaign, shard host, serve job or superset sweep
//! that shares it — the miss path (the batch kernel itself) is only
//! paid once per design point per engine version, ever.
//!
//! Properties, mirroring the cost store and the campaign result sink:
//!
//! * **self-contained rows** — every line carries the fingerprint, the
//!   explicit key fields and the eleven [`SimOutput`] numbers, plus the
//!   [`super::key::key_hash`] id recomputed on load, so corrupt or
//!   hand-edited rows are detected and skipped rather than served;
//! * **bit-exact round trip** — floats use Rust's shortest round-trip
//!   formatting, so a warm run restores the *identical* bits a cold run
//!   computed (the half-warm fig5/sink byte-equality golden depends on
//!   this);
//! * **kill-safe appends** — rows are appended in one buffered write
//!   and flushed per chunk; a torn (newline-less) tail left by a kill
//!   is detected on open and terminated before the next append;
//! * **first record wins** — duplicate keys collapse, conflicting
//!   payloads keep the first and are counted; [`SimStore::gc`]
//!   compacts the file (drops malformed/duplicate/conflicting lines)
//!   with an atomic tmp-file + rename rewrite.
//!
//! Rows simulated under different scoring contexts coexist in one file
//! (a fleet can share a single store across stub and pjrt hosts);
//! lookups are always fingerprint-filtered, and [`super::Key::engine`]
//! quarantines rows from older kernels inside a context.

use super::key::{key_hash, Key};
use crate::error::{Error, Result};
use crate::sched::SimOutput;
use crate::util::jsonl::{field, path_with_suffix};
use crate::util::log;
use std::collections::btree_map::Entry;
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// Schema tag carried by every row.
pub const SCHEMA: &str = "sim-store/v1";

/// Accounting from opening (or gc-ing) a store file.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LoadReport {
    /// Parseable, hash-valid rows read.
    pub records: usize,
    /// Lines that failed to parse or failed the key-hash check.
    pub malformed: usize,
    /// Identical repeats of an already-loaded key, collapsed.
    pub duplicates: usize,
    /// Same-key rows with differing payloads (first wins).
    pub conflicts: usize,
    /// Whether the file ended in a torn (newline-less) tail.
    pub torn_tail: bool,
}

/// A loaded simulation store: the full on-disk row set indexed by
/// fingerprint, then key (nested so the per-unit probe on the dispatch
/// path never re-hashes the fingerprint), plus the append path.
#[derive(Debug)]
pub struct SimStore {
    path: PathBuf,
    rows: BTreeMap<String, BTreeMap<Key, SimOutput>>,
    report: LoadReport,
    /// True while the on-disk file still ends in a torn tail (repaired
    /// lazily by the next append).
    torn_tail: bool,
}

impl SimStore {
    /// Open a store, loading every valid row. A missing file is an
    /// empty store (created on first append); unreadable files and
    /// malformed *rows* are not fatal — rows are skipped and counted —
    /// but a real read error on an existing file is.
    pub fn open(path: impl Into<PathBuf>) -> Result<SimStore> {
        let path = path.into();
        let mut store = SimStore {
            path,
            rows: BTreeMap::new(),
            report: LoadReport::default(),
            torn_tail: false,
        };
        if !store.path.exists() {
            return Ok(store);
        }
        let text = std::fs::read_to_string(&store.path)
            .map_err(|e| Error::io(format!("read sim store {}", store.path.display()), e))?;
        store.report.torn_tail = !text.is_empty() && !text.ends_with('\n');
        store.torn_tail = store.report.torn_tail;
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let Some((fp, key, out)) = parse_line(line) else {
                store.report.malformed += 1;
                continue;
            };
            match store.rows.entry(fp).or_default().entry(key) {
                Entry::Occupied(prev) => {
                    if bits(prev.get()) == bits(&out) {
                        store.report.duplicates += 1;
                    } else {
                        store.report.conflicts += 1;
                    }
                }
                Entry::Vacant(slot) => {
                    slot.insert(out);
                    store.report.records += 1;
                }
            }
        }
        if store.report.malformed > 0 || store.report.conflicts > 0 {
            log::warn(format!(
                "sim store {}: skipped {} malformed line(s), kept first of {} conflict(s)",
                store.path.display(),
                store.report.malformed,
                store.report.conflicts
            ));
        }
        Ok(store)
    }

    /// The file this store persists to.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Load-time accounting (what `repro sim-store stat` prints).
    pub fn report(&self) -> LoadReport {
        self.report
    }

    /// Distinct `(fingerprint, key)` rows held.
    pub fn len(&self) -> usize {
        self.rows.values().map(BTreeMap::len).sum()
    }

    /// True when no rows are held.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look one row up within a scoring context (this runs once per
    /// memo-missed work unit on the campaign dispatch path).
    pub fn get(&self, fingerprint: &str, key: &Key) -> Option<SimOutput> {
        self.rows.get(fingerprint)?.get(key).cloned()
    }

    /// Row counts per fingerprint, sorted (for `stat`).
    pub fn per_fingerprint(&self) -> Vec<(String, usize)> {
        self.rows.iter().map(|(fp, m)| (fp.clone(), m.len())).collect()
    }

    /// Append freshly simulated rows (skipping keys already held) and
    /// flush, creating the file/parents on first use and terminating a
    /// torn tail so it can never merge with a fresh row. One buffered
    /// write per call: the campaign flushes after each worker chunk, so
    /// a killed campaign still warms the next one.
    pub fn append(&mut self, fingerprint: &str, fresh: &[(Key, SimOutput)]) -> Result<()> {
        let mut buf = String::new();
        if self.torn_tail {
            buf.push('\n');
        }
        if !fresh.is_empty() {
            let held = self.rows.entry(fingerprint.to_string()).or_default();
            for (key, out) in fresh {
                if held.contains_key(key) {
                    continue;
                }
                buf.push_str(&record_line(fingerprint, key, out));
                buf.push('\n');
                held.insert(key.clone(), out.clone());
            }
        }
        if buf.is_empty() {
            return Ok(());
        }
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .map_err(|e| Error::io(format!("create {}", dir.display()), e))?;
            }
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .map_err(|e| Error::io(format!("open sim store {}", self.path.display()), e))?;
        f.write_all(buf.as_bytes())
            .map_err(|e| Error::io(format!("append sim store {}", self.path.display()), e))?;
        f.flush()
            .map_err(|e| Error::io(format!("flush sim store {}", self.path.display()), e))?;
        self.torn_tail = false;
        Ok(())
    }

    /// Compact the file: rewrite the held row set (sorted by
    /// fingerprint, then key — byte-stable) through a tmp file + atomic
    /// rename, dropping every malformed/duplicate/conflicting line the
    /// load skipped. Returns how many lines the rewrite shed.
    pub fn gc(&mut self) -> Result<usize> {
        let dropped = self.report.malformed
            + self.report.duplicates
            + self.report.conflicts
            + usize::from(self.report.torn_tail);
        let mut buf = String::new();
        for (fp, held) in &self.rows {
            for (key, out) in held {
                buf.push_str(&record_line(fp, key, out));
                buf.push('\n');
            }
        }
        let tmp = path_with_suffix(&self.path, ".tmp");
        std::fs::write(&tmp, buf.as_bytes())
            .map_err(|e| Error::io(format!("write {}", tmp.display()), e))?;
        std::fs::rename(&tmp, &self.path)
            .map_err(|e| Error::io(format!("rename {} over store", tmp.display()), e))?;
        self.torn_tail = false;
        self.report = LoadReport { records: self.len(), ..LoadReport::default() };
        Ok(dropped)
    }

    /// The whole row set as a CSV document (for `export`), sorted like
    /// [`SimStore::gc`] writes.
    pub fn export_csv(&self) -> String {
        let mut s = String::from(concat!(
            "fingerprint,trace,nodes,mem,unroll,word_bytes,alus,engine,",
            "cycles,period_ns,time_ns,mem_area_um2,fu_area_um2,area_um2,",
            "power_mw,dyn_energy_pj,mem_accesses,port_stalls,stall_cycles\n"
        ));
        for (fp, held) in &self.rows {
            for (k, o) in held {
                s.push_str(&format!(
                    "{fp},{:016x},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
                    k.trace_hash,
                    k.nodes,
                    k.mem,
                    k.unroll,
                    k.word_bytes,
                    k.alus,
                    k.engine,
                    o.cycles,
                    o.period_ns,
                    o.time_ns,
                    o.mem_area_um2,
                    o.fu_area_um2,
                    o.area_um2,
                    o.power_mw,
                    o.dyn_energy_pj,
                    o.mem_accesses,
                    o.port_stalls,
                    o.stall_cycles,
                ));
            }
        }
        s
    }
}

/// Accounting from one [`pool`] call (what `repro merge
/// --pool-sim-stores` prints).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolReport {
    /// Input store files read.
    pub inputs: usize,
    /// Distinct rows held across the inputs (after each input's own
    /// dedupe).
    pub rows_seen: usize,
    /// Rows appended to the output store.
    pub added: usize,
    /// Rows the output already held with the identical payload.
    pub already_held: usize,
    /// Rows whose key was already held with a *different* payload —
    /// the earlier row wins (pre-existing output rows beat inputs,
    /// earlier inputs beat later ones).
    pub conflicts: usize,
    /// Malformed/corrupt lines skipped across the inputs.
    pub malformed: usize,
}

/// Reconcile N shard-fleet stores into one: open (or create) `out`,
/// absorb every input's rows with first-wins semantics, and append the
/// genuinely new rows in one sorted batch per `(input, fingerprint)` —
/// the multi-host closing move of a sharded campaign, where each host
/// accumulated its own simulation rows and the fleet wants one warm
/// artifact.
///
/// First-wins ordering: rows already in `out` beat every input, and an
/// earlier input beats a later one (matching the cost-store pool and
/// load-time conflict rules). Conflicts can only arise across
/// *different* engines or scoring contexts mis-sharing a fingerprint —
/// counted and kept-first, never merged.
pub fn pool<P: AsRef<Path>>(inputs: &[P], out: &Path) -> Result<(SimStore, PoolReport)> {
    let mut store = SimStore::open(out)?;
    let mut report = PoolReport { inputs: inputs.len(), ..PoolReport::default() };
    for input in inputs {
        let src = SimStore::open(input.as_ref())?;
        report.malformed += src.report().malformed;
        for (fp, held) in &src.rows {
            let mut fresh: Vec<(Key, SimOutput)> = Vec::new();
            for (key, out_row) in held {
                report.rows_seen += 1;
                match store.get(fp, key) {
                    Some(prev) if bits(&prev) == bits(out_row) => report.already_held += 1,
                    Some(_) => report.conflicts += 1,
                    None => fresh.push((key.clone(), out_row.clone())),
                }
            }
            report.added += fresh.len();
            store.append(fp, &fresh)?;
        }
    }
    Ok((store, report))
}

/// The raw bit patterns of an output (exact comparison: duplicate vs
/// conflict must not be fooled by NaN or -0.0 semantics).
fn bits(o: &SimOutput) -> [u64; 11] {
    [
        o.cycles,
        u64::from(o.period_ns.to_bits()),
        o.time_ns.to_bits(),
        u64::from(o.mem_area_um2.to_bits()),
        u64::from(o.fu_area_um2.to_bits()),
        u64::from(o.area_um2.to_bits()),
        u64::from(o.power_mw.to_bits()),
        o.dyn_energy_pj.to_bits(),
        o.mem_accesses,
        o.port_stalls,
        o.stall_cycles,
    ]
}

/// Emit one store row. Floats use shortest round-trip formatting, so
/// `parse_line(record_line(..))` reproduces the identical bits.
pub fn record_line(fingerprint: &str, key: &Key, out: &SimOutput) -> String {
    format!(
        concat!(
            "{{\"schema\":\"{}\",\"k\":\"{:016x}\",\"fp\":\"{}\",",
            "\"trace\":\"{:016x}\",\"nodes\":{},\"mem\":\"{}\",",
            "\"unroll\":{},\"word_bytes\":{},\"alus\":{},\"engine\":{},",
            "\"cycles\":{},\"period_ns\":{},\"time_ns\":{},",
            "\"mem_area_um2\":{},\"fu_area_um2\":{},\"area_um2\":{},",
            "\"power_mw\":{},\"dyn_energy_pj\":{},\"mem_accesses\":{},",
            "\"port_stalls\":{},\"stall_cycles\":{}}}"
        ),
        SCHEMA,
        key_hash(fingerprint, key),
        fingerprint,
        key.trace_hash,
        key.nodes,
        key.mem,
        key.unroll,
        key.word_bytes,
        key.alus,
        key.engine,
        out.cycles,
        out.period_ns,
        out.time_ns,
        out.mem_area_um2,
        out.fu_area_um2,
        out.area_um2,
        out.power_mw,
        out.dyn_energy_pj,
        out.mem_accesses,
        out.port_stalls,
        out.stall_cycles,
    )
}

/// Parse one row back. `None` for malformed lines, foreign schemas, or
/// rows whose recorded key hash does not match the recomputed one
/// (corruption / hand edits) — the store treats all of those as absent.
pub fn parse_line(line: &str) -> Option<(String, Key, SimOutput)> {
    if field(line, "schema")? != SCHEMA {
        return None;
    }
    let fp = field(line, "fp")?.to_string();
    let key = Key {
        trace_hash: u64::from_str_radix(field(line, "trace")?, 16).ok()?,
        nodes: field(line, "nodes")?.parse().ok()?,
        mem: field(line, "mem")?.to_string(),
        unroll: field(line, "unroll")?.parse().ok()?,
        word_bytes: field(line, "word_bytes")?.parse().ok()?,
        alus: field(line, "alus")?.parse().ok()?,
        engine: field(line, "engine")?.parse().ok()?,
    };
    let recorded = u64::from_str_radix(field(line, "k")?, 16).ok()?;
    if recorded != key_hash(&fp, &key) {
        return None;
    }
    let out = SimOutput {
        cycles: field(line, "cycles")?.parse().ok()?,
        period_ns: field(line, "period_ns")?.parse().ok()?,
        time_ns: field(line, "time_ns")?.parse().ok()?,
        mem_area_um2: field(line, "mem_area_um2")?.parse().ok()?,
        fu_area_um2: field(line, "fu_area_um2")?.parse().ok()?,
        area_um2: field(line, "area_um2")?.parse().ok()?,
        power_mw: field(line, "power_mw")?.parse().ok()?,
        dyn_energy_pj: field(line, "dyn_energy_pj")?.parse().ok()?,
        mem_accesses: field(line, "mem_accesses")?.parse().ok()?,
        port_stalls: field(line, "port_stalls")?.parse().ok()?,
        stall_cycles: field(line, "stall_cycles")?.parse().ok()?,
    };
    Some((fp, key, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::ENGINE_VERSION;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("amm_dse_sim_store_unit");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join(name);
        let _ = std::fs::remove_file(&path);
        path
    }

    fn sample_key(mem: &str, unroll: u32) -> Key {
        Key {
            trace_hash: 0x1234_5678_9abc_def0,
            nodes: 2048,
            unroll,
            word_bytes: 8,
            alus: 4,
            mem: mem.into(),
            engine: ENGINE_VERSION,
        }
    }

    fn sample_out() -> SimOutput {
        SimOutput {
            cycles: 123_456,
            period_ns: 1.2345678,
            time_ns: 152_415.7,
            mem_area_um2: 98765.4,
            fu_area_um2: 1234.5,
            area_um2: 99999.9,
            power_mw: 3.1415927,
            dyn_energy_pj: 424_242.42,
            mem_accesses: 65_536,
            port_stalls: 512,
            stall_cycles: 768,
        }
    }

    #[test]
    fn rows_round_trip_bit_for_bit() {
        let key = sample_key("xor4r2w", 8);
        let out = sample_out();
        let line = record_line("rust-mirror/45nm/abc", &key, &out);
        let (fp, k, o) = parse_line(&line).expect("must parse");
        assert_eq!(fp, "rust-mirror/45nm/abc");
        assert_eq!(k, key);
        assert_eq!(bits(&o), bits(&out), "shortest float reprs reparse to identical bits");
    }

    #[test]
    fn corrupt_rows_and_foreign_schemas_parse_to_none() {
        let line = record_line("fp", &sample_key("bank4", 1), &sample_out());
        assert!(parse_line("").is_none());
        assert!(parse_line("{\"schema\":\"cost-store/v1\"}").is_none());
        assert!(parse_line(&line[..line.len() / 2]).is_none(), "torn tail must not parse");
        // flipping a field invalidates the recorded key hash
        let tampered = line.replace("\"unroll\":1", "\"unroll\":2");
        assert_ne!(line, tampered);
        assert!(parse_line(&tampered).is_none(), "hash check must catch edits");
    }

    #[test]
    fn store_appends_persist_and_reload() {
        let path = tmp("roundtrip.jsonl");
        let mut store = SimStore::open(&path).unwrap();
        assert!(store.is_empty());
        let rows =
            vec![(sample_key("bank4", 1), sample_out()), (sample_key("xor4r2w", 4), sample_out())];
        store.append("fp-a", &rows).unwrap();
        assert_eq!(store.len(), 2);
        // re-appending held keys writes nothing new
        store.append("fp-a", &rows).unwrap();
        let reloaded = SimStore::open(&path).unwrap();
        assert_eq!(reloaded.len(), 2);
        assert_eq!(reloaded.report().records, 2);
        assert_eq!(reloaded.report().duplicates, 0, "held keys must not re-append");
        let got = reloaded.get("fp-a", &sample_key("bank4", 1)).unwrap();
        assert_eq!(bits(&got), bits(&sample_out()));
    }

    #[test]
    fn fingerprints_and_engine_versions_isolate_rows() {
        let path = tmp("isolation.jsonl");
        let mut store = SimStore::open(&path).unwrap();
        let key = sample_key("mp2x", 2);
        store.append("rust-mirror/45nm/aaaa", &[(key.clone(), sample_out())]).unwrap();
        // stub-simulated rows are invisible to a pjrt-fingerprinted lookup
        assert!(store.get("pjrt/cost_model/bbbb", &key).is_none());
        assert!(store.get("rust-mirror/45nm/aaaa", &key).is_some());
        // a bumped engine version quarantines the old row in-context
        let newer = Key { engine: key.engine + 1, ..key.clone() };
        assert!(store.get("rust-mirror/45nm/aaaa", &newer).is_none());
        // both contexts coexist in one file
        let mut other = sample_out();
        other.cycles = 1;
        store.append("pjrt/cost_model/bbbb", &[(key.clone(), other)]).unwrap();
        let reloaded = SimStore::open(&path).unwrap();
        assert_eq!(reloaded.len(), 2);
        assert_eq!(reloaded.get("rust-mirror/45nm/aaaa", &key).unwrap().cycles, 123_456);
        assert_eq!(reloaded.get("pjrt/cost_model/bbbb", &key).unwrap().cycles, 1);
        let per_fp = reloaded.per_fingerprint();
        assert_eq!(per_fp.len(), 2);
        assert!(per_fp.iter().all(|(_, n)| *n == 1), "{per_fp:?}");
    }

    #[test]
    fn torn_tails_are_detected_and_repaired_by_the_next_append() {
        let path = tmp("torn.jsonl");
        let mut store = SimStore::open(&path).unwrap();
        store.append("fp", &[(sample_key("bank1", 1), sample_out())]).unwrap();
        // simulate a kill mid-append: a newline-less fragment
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, format!("{full}{}", &full[..30])).unwrap();
        let mut reopened = SimStore::open(&path).unwrap();
        assert!(reopened.report().torn_tail);
        assert_eq!(reopened.len(), 1, "the torn fragment must not parse");
        reopened.append("fp", &[(sample_key("bank1", 2), sample_out())]).unwrap();
        // the repair newline keeps the fresh row parseable
        let repaired = SimStore::open(&path).unwrap();
        assert!(!repaired.report().torn_tail);
        assert_eq!(repaired.len(), 2);
        assert_eq!(repaired.report().malformed, 1, "the terminated fragment is skipped");
    }

    #[test]
    fn gc_compacts_duplicates_conflicts_and_garbage() {
        let path = tmp("gc.jsonl");
        let key = sample_key("lvt4r2w", 4);
        let good = record_line("fp", &key, &sample_out());
        let mut conflicted = sample_out();
        conflicted.cycles += 1;
        let conflict = record_line("fp", &key, &conflicted);
        std::fs::write(&path, format!("{good}\ngarbage line\n{good}\n{conflict}\n")).unwrap();
        let mut store = SimStore::open(&path).unwrap();
        let rep = store.report();
        assert_eq!((rep.records, rep.malformed, rep.duplicates, rep.conflicts), (1, 1, 1, 1));
        // first record wins the conflict
        assert_eq!(store.get("fp", &key).unwrap().cycles, sample_out().cycles);
        let dropped = store.gc().unwrap();
        assert_eq!(dropped, 3);
        let clean = SimStore::open(&path).unwrap();
        let rep = clean.report();
        assert_eq!((rep.records, rep.malformed, rep.duplicates, rep.conflicts), (1, 0, 0, 0));
        // gc output is byte-stable
        let once = std::fs::read_to_string(&path).unwrap();
        SimStore::open(&path).unwrap().gc().unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), once);
    }

    #[test]
    fn pool_reconciles_shard_stores_first_wins() {
        let a_path = tmp("pool_a.jsonl");
        let b_path = tmp("pool_b.jsonl");
        let out_path = tmp("pool_out.jsonl");
        let shared = sample_key("bank4", 1);
        let only_a = sample_key("bank4", 4);
        let only_b = sample_key("xor4r2w", 1);
        let mut a = SimStore::open(&a_path).unwrap();
        a.append("fp", &[(shared.clone(), sample_out()), (only_a, sample_out())]).unwrap();
        let mut b = SimStore::open(&b_path).unwrap();
        let mut divergent = sample_out();
        divergent.cycles += 1;
        b.append("fp", &[(shared.clone(), divergent), (only_b, sample_out())]).unwrap();
        let (pooled, rep) = pool(&[&a_path, &b_path], &out_path).unwrap();
        assert_eq!(rep.inputs, 2);
        assert_eq!(rep.rows_seen, 4);
        assert_eq!(rep.added, 3, "shared key pools once");
        assert_eq!(rep.conflicts, 1, "divergent payload for the shared key");
        assert_eq!(rep.already_held, 0);
        assert_eq!(pooled.len(), 3);
        // first input wins the conflict
        assert_eq!(pooled.get("fp", &shared).unwrap().cycles, sample_out().cycles);
        // the output is a normal store: reload agrees
        let reloaded = SimStore::open(&out_path).unwrap();
        assert_eq!(reloaded.len(), 3);
        assert_eq!(reloaded.report().records, 3);
        // pooling again is a no-op: everything already held
        let (_, again) = pool(&[&a_path, &b_path], &out_path).unwrap();
        assert_eq!(again.added, 0);
        assert_eq!(again.already_held, 3);
        assert_eq!(again.conflicts, 1, "the divergent row still conflicts");
        assert_eq!(SimStore::open(&out_path).unwrap().len(), 3);
    }

    #[test]
    fn export_csv_lists_every_row() {
        let path = tmp("export.jsonl");
        let mut store = SimStore::open(&path).unwrap();
        store.append("fp-b", &[(sample_key("xor4r2w", 1), sample_out())]).unwrap();
        store.append("fp-a", &[(sample_key("bank4", 1), sample_out())]).unwrap();
        let csv = store.export_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3, "{csv}");
        assert!(lines[0].starts_with("fingerprint,trace,nodes,mem"));
        // sorted by fingerprint then key
        assert!(lines[1].starts_with("fp-a,"));
        assert!(lines[1].contains(",bank4,"));
        assert!(lines[2].starts_with("fp-b,"));
        assert!(lines[2].contains(",xor4r2w,"));
    }
}
