//! Canonical simulation-result keys.
//!
//! A persisted simulation row is only reusable when *everything* the
//! scheduler consumed matches:
//!
//! * the **trace** — content hash + node count of the compiled trace
//!   ([`crate::sched::CompiledTrace::content_hash`]), so two benchmarks
//!   (or two scales, or two `synth:` dial settings) can never satisfy
//!   each other;
//! * the **knobs** — unroll / word size / ALU count, exactly the
//!   [`crate::sched::Knobs`] the engine schedules under;
//! * the **design** — the memory organization's registry id (port
//!   model, banking, AMM family) plus the scoring-context
//!   *fingerprint* the design's cost numbers came from (see
//!   [`crate::cost::key`]): a [`SimOutput`](crate::sched::SimOutput)
//!   folds cost-patched fields (`period_ns`, energies, areas) into
//!   every row, so rows scored under the stub mirror and rows scored
//!   under the PJRT artifact must never cross-resolve;
//! * the **engine** — [`crate::sched::ENGINE_VERSION`], bumped on any
//!   semantic kernel change, so a fixed or re-modeled scheduler starts
//!   cold instead of replaying stale results.
//!
//! [`key_hash`] combines the fingerprint and the key into the 64-bit
//! FNV-1a id each `sim-store/v1` row carries; the store recomputes it
//! on load, so corrupted or hand-edited rows are dropped, not served.

use crate::mem::MemDesign;
use crate::sched::{CompiledTrace, Knobs, ENGINE_VERSION};
use crate::util::hash::{fnv1a, FNV_OFFSET};

/// The canonical simulation key: everything one scheduler run depends
/// on besides the scoring-context fingerprint (kept separate, like the
/// cost store's, so one file can hold rows from several contexts).
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Key {
    /// Content hash of the compiled trace (arrays + node stream).
    pub trace_hash: u64,
    /// Node count of the compiled trace (cheap mismatch tripwire).
    pub nodes: u64,
    /// Unroll factor.
    pub unroll: u32,
    /// Scratchpad word size, bytes.
    pub word_bytes: u32,
    /// ALU issue slots.
    pub alus: u32,
    /// Memory-design registry id (e.g. `xor4r2w`).
    pub mem: String,
    /// [`ENGINE_VERSION`] the row was simulated under.
    pub engine: u32,
}

impl Key {
    /// The key of one work unit: a compiled trace, the knobs it will be
    /// scheduled under, and the (cost-patched) design. The single home
    /// of this projection — campaign probe and record both call it.
    pub fn of(compiled: &CompiledTrace<'_>, knobs: &Knobs, design: &MemDesign) -> Key {
        Key {
            trace_hash: compiled.content_hash(),
            nodes: compiled.trace().len() as u64,
            unroll: knobs.unroll,
            word_bytes: knobs.word_bytes,
            alus: knobs.alus,
            mem: design.id.clone(),
            engine: ENGINE_VERSION,
        }
    }
}

/// Stable 64-bit id of one `(fingerprint, key)` pair: FNV-1a over the
/// fingerprint bytes, a NUL, the mem id bytes, a NUL, then the numeric
/// fields as little-endian words. Part of the `sim-store/v1` on-disk
/// contract — change it and every existing store reads as corrupt.
pub fn key_hash(fingerprint: &str, key: &Key) -> u64 {
    let mut h = fnv1a(FNV_OFFSET, fingerprint.as_bytes());
    h = fnv1a(h, &[0u8]);
    h = fnv1a(h, key.mem.as_bytes());
    h = fnv1a(h, &[0u8]);
    h = fnv1a(h, &key.trace_hash.to_le_bytes());
    h = fnv1a(h, &key.nodes.to_le_bytes());
    for field in [key.unroll, key.word_bytes, key.alus, key.engine] {
        h = fnv1a(h, &field.to_le_bytes());
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Key {
        Key {
            trace_hash: 0xdead_beef_cafe_f00d,
            nodes: 4096,
            unroll: 8,
            word_bytes: 8,
            alus: 4,
            mem: "xor4r2w".into(),
            engine: ENGINE_VERSION,
        }
    }

    #[test]
    fn key_hash_is_stable_and_separates_every_field() {
        let k = sample();
        assert_eq!(key_hash("fp", &k), key_hash("fp", &k), "deterministic");
        assert_ne!(key_hash("fp", &k), key_hash("other", &k), "fingerprint matters");
        for tweak in [
            Key { trace_hash: k.trace_hash ^ 1, ..k.clone() },
            Key { nodes: k.nodes + 1, ..k.clone() },
            Key { unroll: k.unroll + 1, ..k.clone() },
            Key { word_bytes: k.word_bytes * 2, ..k.clone() },
            Key { alus: k.alus + 1, ..k.clone() },
            Key { mem: "lvt4r2w".into(), ..k.clone() },
            Key { engine: k.engine + 1, ..k.clone() },
        ] {
            assert_ne!(key_hash("fp", &k), key_hash("fp", &tweak), "{tweak:?}");
        }
        // NUL separators keep variable-length prefixes unambiguous
        let a = Key { mem: "ab".into(), ..k.clone() };
        let b = Key { mem: "a".into(), ..k };
        assert_ne!(key_hash("x", &a), key_hash("xb", &b));
    }

    #[test]
    fn key_of_projects_the_unit() {
        let wl = crate::suite::generate("stencil2d", crate::suite::Scale::Tiny);
        let compiled = CompiledTrace::new(&wl.trace, 8);
        let knobs = Knobs { unroll: 4, word_bytes: 8, alus: 2 };
        let design = crate::mem::MemKind::Banked { banks: 4 }.build(compiled.depth(), 64);
        let key = Key::of(&compiled, &knobs, &design);
        assert_eq!(key.trace_hash, compiled.content_hash());
        assert_eq!(key.nodes, wl.trace.len() as u64);
        assert_eq!((key.unroll, key.word_bytes, key.alus), (4, 8, 2));
        assert_eq!(key.mem, design.id);
        assert_eq!(key.engine, ENGINE_VERSION);
    }
}
