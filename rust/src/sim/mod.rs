//! Tiered simulation-result subsystem.
//!
//! A campaign's hot path is the cycle-accurate scheduler itself: the
//! cost stack ([`crate::cost`]) already makes warm re-runs issue zero
//! backend *cost* batches, but every design point was still
//! re-*simulated* unless the exact same sink was resumed. Simulation is
//! deterministic for a given [`Key`] (trace content + knobs + design +
//! engine version) within one scoring context, so — like macro costs —
//! results are treated as **artifacts**. Every work unit flows through
//! one [`SimStack`] of three tiers, each a cheaper cache in front of
//! the next:
//!
//! 1. **memo** — an in-process map; repeated dispatch inside one
//!    process (serve jobs sharing a coordinator, sequential campaigns,
//!    perf probes) never re-schedules a unit it has already seen;
//! 2. **store** — the persistent on-disk [`SimStore`] (`sim-store/v1`
//!    append-only JSONL, see [`store`]): a campaign opens it next to
//!    its sink and flushes newly simulated rows after each worker
//!    chunk, so a *new process* — a fresh sink, another shard host, a
//!    superset sweep — starts warm and re-simulates only the delta.
//!    Rows are keyed by a stable hash of the canonical [`Key`] plus
//!    the scoring-context **fingerprint** (see [`key`]), so stub- and
//!    pjrt-costed results can never cross-contaminate, and
//!    [`crate::sched::ENGINE_VERSION`] quarantines rows from older
//!    kernels;
//! 3. **simulate** — the campaign's lane-batched kernel itself. Only
//!    misses are re-packed into lane groups and scheduled; hits flow
//!    straight to the sink writer.
//!
//! Unlike the cost stack, the compute tier is *not* inside the stack:
//! the campaign owns lane packing and the worker pool, so [`SimStack`]
//! exposes probe/record halves ([`SimStack::probe`] /
//! [`SimStack::record_all`]) instead of a provider trait.
//! [`SimCounters`] exposes hit/miss accounting — the campaign reports
//! it (`memoized` in the summary, sidecar and outcome) and tests pin
//! the "warm run simulates zero points" contract.

pub mod key;
pub mod store;

pub use key::{key_hash, Key};
pub use store::SimStore;

use crate::error::Result;
use crate::sched::SimOutput;
use crate::util::log;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Snapshot of a [`SimStack`]'s accounting. Campaigns diff two
/// snapshots ([`SimCounters::since`]) to report their own share of a
/// long-lived coordinator's traffic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SimCounters {
    /// Units answered by the in-process memo tier.
    pub memo_hits: usize,
    /// Units answered by the persistent store tier.
    pub store_hits: usize,
    /// Units that had to be simulated.
    pub misses: usize,
}

impl SimCounters {
    /// Total cache hits (memo + store) — the campaign's `memoized`.
    pub fn hits(&self) -> usize {
        self.memo_hits + self.store_hits
    }

    /// The delta between this snapshot and an earlier one.
    pub fn since(&self, earlier: &SimCounters) -> SimCounters {
        SimCounters {
            memo_hits: self.memo_hits.saturating_sub(earlier.memo_hits),
            store_hits: self.store_hits.saturating_sub(earlier.store_hits),
            misses: self.misses.saturating_sub(earlier.misses),
        }
    }
}

/// The memo + store tiers in front of the campaign's batch kernel (see
/// the module docs). Interior-mutable so a shared `&Coordinator` can
/// probe from many workers and a campaign can attach a store without
/// exclusive access.
pub struct SimStack {
    fingerprint: String,
    memo: Mutex<HashMap<Key, SimOutput>>,
    store: Mutex<Option<SimStore>>,
    memo_hits: AtomicUsize,
    store_hits: AtomicUsize,
    misses: AtomicUsize,
}

impl std::fmt::Debug for SimStack {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SimStack")
            .field("fingerprint", &self.fingerprint)
            .field("counters", &self.counters())
            .finish()
    }
}

impl SimStack {
    /// A stack persisting under `fingerprint` — the same scoring-context
    /// fingerprint the cost stack uses, since every [`SimOutput`] folds
    /// cost-patched numbers in. Starts with an empty memo and no store
    /// attached.
    pub fn new(fingerprint: String) -> Self {
        SimStack {
            fingerprint,
            memo: Mutex::new(HashMap::new()),
            store: Mutex::new(None),
            memo_hits: AtomicUsize::new(0),
            store_hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    /// The scoring-context fingerprint rows are persisted under.
    pub fn fingerprint(&self) -> &str {
        &self.fingerprint
    }

    /// Attach (open or create) the persistent store at `path`. A store
    /// already open at the same path is kept; a different path replaces
    /// it (with a warning — one stack persists to one store at a time).
    pub fn open_store(&self, path: &Path) -> Result<()> {
        let mut slot = self.store.lock().expect("sim store slot poisoned");
        if let Some(open) = slot.as_ref() {
            if open.path() == path {
                return Ok(());
            }
            log::warn(format!(
                "sim stack: replacing open store {} with {}",
                open.path().display(),
                path.display()
            ));
        }
        *slot = Some(SimStore::open(path)?);
        Ok(())
    }

    /// Path of the attached store, if any.
    pub fn store_path(&self) -> Option<PathBuf> {
        self.store
            .lock()
            .expect("sim store slot poisoned")
            .as_ref()
            .map(|s| s.path().to_path_buf())
    }

    /// Hit/miss accounting since construction.
    pub fn counters(&self) -> SimCounters {
        SimCounters {
            memo_hits: self.memo_hits.load(Ordering::Relaxed),
            store_hits: self.store_hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Probe the cache tiers for one work unit. `Some` is a memoized
    /// result (bit-identical to what simulation would produce); `None`
    /// means the unit must be simulated and later fed back through
    /// [`SimStack::record_all`]. A memo hit the attached store never
    /// saw (it may have been attached — or swapped — after the unit was
    /// simulated) is backfilled, so the store's content does not depend
    /// on attach order.
    pub fn probe(&self, key: &Key) -> Option<SimOutput> {
        // one lock scope per probe, memo before store (every site that
        // holds both acquires in this order)
        let mut memo = self.memo.lock().expect("sim memo poisoned");
        let mut store = self.store.lock().expect("sim store slot poisoned");
        if let Some(out) = memo.get(key) {
            let out = out.clone();
            self.memo_hits.fetch_add(1, Ordering::Relaxed);
            if let Some(s) = store.as_mut() {
                if s.get(&self.fingerprint, key).is_none() {
                    let row = [(key.clone(), out.clone())];
                    if let Err(e) = s.append(&self.fingerprint, &row) {
                        log::warn(format!(
                            "sim store {}: {e} (row stays memoized; persistence skipped)",
                            s.path().display()
                        ));
                    }
                }
            }
            return Some(out);
        }
        if let Some(out) = store.as_ref().and_then(|s| s.get(&self.fingerprint, key)) {
            memo.insert(key.clone(), out.clone());
            self.store_hits.fetch_add(1, Ordering::Relaxed);
            return Some(out);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Record freshly simulated units: memoize them and flush the
    /// genuinely new ones to the attached store in one buffered append.
    /// Workers call this per chunk, so a killed campaign still warms
    /// the next one — but persistence is a cache, not a result: an
    /// unwritable store must not fail a fully simulated campaign.
    pub fn record_all(&self, fresh: &[(Key, SimOutput)]) {
        if fresh.is_empty() {
            return;
        }
        let mut persist: Vec<(Key, SimOutput)> = Vec::new();
        {
            let mut memo = self.memo.lock().expect("sim memo poisoned");
            for (key, out) in fresh {
                // a unit recorded twice (lane-group overlap) persists once
                if memo.insert(key.clone(), out.clone()).is_none() {
                    persist.push((key.clone(), out.clone()));
                }
            }
        }
        if persist.is_empty() {
            return;
        }
        let mut store = self.store.lock().expect("sim store slot poisoned");
        if let Some(s) = store.as_mut() {
            if let Err(e) = s.append(&self.fingerprint, &persist) {
                log::warn(format!(
                    "sim store {}: {e} (rows stay memoized; persistence skipped)",
                    s.path().display()
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::ENGINE_VERSION;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("amm_dse_sim_stack_unit");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join(name);
        let _ = std::fs::remove_file(&path);
        path
    }

    fn keys() -> Vec<Key> {
        ["bank4", "xor4r2w", "mp2x"]
            .iter()
            .map(|mem| Key {
                trace_hash: 0xfeed_f00d,
                nodes: 128,
                unroll: 4,
                word_bytes: 8,
                alus: 4,
                mem: (*mem).into(),
                engine: ENGINE_VERSION,
            })
            .collect()
    }

    fn out_for(k: &Key) -> SimOutput {
        SimOutput {
            cycles: 1000 + k.mem.len() as u64,
            period_ns: 1.25,
            time_ns: 1250.0,
            ..SimOutput::default()
        }
    }

    fn simulate_all(stack: &SimStack, keys: &[Key]) -> Vec<SimOutput> {
        // the campaign's probe → simulate-misses → record loop in
        // miniature
        let mut outs: Vec<Option<SimOutput>> = keys.iter().map(|k| stack.probe(k)).collect();
        let fresh: Vec<(Key, SimOutput)> = keys
            .iter()
            .zip(&outs)
            .filter(|(_, o)| o.is_none())
            .map(|(k, _)| (k.clone(), out_for(k)))
            .collect();
        stack.record_all(&fresh);
        for (k, slot) in keys.iter().zip(outs.iter_mut()) {
            if slot.is_none() {
                *slot = Some(out_for(k));
            }
        }
        outs.into_iter().map(|o| o.unwrap()).collect()
    }

    #[test]
    fn memo_tier_absorbs_repeat_probes() {
        let stack = SimStack::new("fp-test".into());
        let ks = keys();
        let first = simulate_all(&stack, &ks);
        let second = simulate_all(&stack, &ks);
        assert_eq!(first, second);
        let c = stack.counters();
        assert_eq!((c.memo_hits, c.store_hits, c.misses), (3, 0, 3));
        assert_eq!(c.hits(), 3);
    }

    #[test]
    fn store_tier_warms_a_fresh_stack_to_zero_misses() {
        let path = tmp("warm.jsonl");
        let ks = keys();
        let cold = SimStack::new("fp-test".into());
        cold.open_store(&path).unwrap();
        let cold_outs = simulate_all(&cold, &ks);
        assert_eq!(cold.counters().misses, 3);

        // a fresh stack (new process) over the same store: zero misses
        let warm = SimStack::new("fp-test".into());
        warm.open_store(&path).unwrap();
        let warm_outs = simulate_all(&warm, &ks);
        let c = warm.counters();
        assert_eq!(c.misses, 0, "a warm store must absorb every probe");
        assert_eq!(c.store_hits, 3);
        assert_eq!(cold_outs, warm_outs, "stored rows must be bit-exact");
    }

    #[test]
    fn fingerprints_keep_scoring_contexts_cold_for_each_other() {
        let path = tmp("fp_cold.jsonl");
        let ks = keys();
        let a = SimStack::new("fp-a".into());
        a.open_store(&path).unwrap();
        simulate_all(&a, &ks);
        // same store, different fingerprint: everything misses
        let b = SimStack::new("fp-b".into());
        b.open_store(&path).unwrap();
        simulate_all(&b, &ks);
        assert_eq!(b.counters().misses, 3, "foreign-fingerprint rows must not satisfy");
        assert_eq!(b.counters().store_hits, 0);
    }

    #[test]
    fn memo_hits_backfill_a_store_attached_after_recording() {
        let path = tmp("backfill.jsonl");
        let ks = keys();
        let stack = SimStack::new("fp-test".into());
        simulate_all(&stack, &ks);
        assert_eq!(stack.counters().misses, 3);
        stack.open_store(&path).unwrap();
        simulate_all(&stack, &ks);
        assert_eq!(stack.counters().misses, 3, "memo still absorbs the repeat");
        // a fresh stack over the backfilled store is fully warm
        let fresh = SimStack::new("fp-test".into());
        fresh.open_store(&path).unwrap();
        simulate_all(&fresh, &ks);
        assert_eq!(fresh.counters().misses, 0, "backfilled store must warm a new process");
        assert_eq!(fresh.counters().store_hits, 3);
    }

    #[test]
    fn counters_diff_with_since() {
        let stack = SimStack::new("fp".into());
        let ks = keys();
        simulate_all(&stack, &ks);
        let mid = stack.counters();
        simulate_all(&stack, &ks);
        let delta = stack.counters().since(&mid);
        assert_eq!(delta.misses, 0);
        assert_eq!(delta.memo_hits, 3);
        assert_eq!(delta.hits(), 3);
    }

    #[test]
    fn open_store_is_idempotent_per_path() {
        let path = tmp("idem.jsonl");
        let stack = SimStack::new("fp".into());
        stack.open_store(&path).unwrap();
        simulate_all(&stack, &keys());
        // reopening the same path must keep the loaded/written rows
        stack.open_store(&path).unwrap();
        simulate_all(&stack, &keys());
        assert_eq!(stack.counters().misses, 3);
        assert_eq!(stack.store_path().as_deref(), Some(path.as_path()));
    }
}
