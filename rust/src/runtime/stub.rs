//! Stub runtime used when the `pjrt` feature (and its vendored `xla`
//! dependency) is not available. Mirrors the real API so callers
//! compile identically; every constructor fails with
//! [`Error::Runtime`], which the coordinator treats as "fall back to
//! the pure-Rust cost mirror".

use crate::error::{Error, Result};
use std::path::{Path, PathBuf};
use std::rc::Rc;

const UNAVAILABLE: &str =
    "pjrt support not compiled in (enable the `pjrt` feature and vendor the `xla` crate)";

/// A loaded, compiled executable (stub: cannot be constructed).
pub struct Executable {
    /// Artifact name.
    pub name: String,
    _private: (),
}

impl Executable {
    /// Run with f32 input buffers of the given shapes; returns the
    /// flattened f32 outputs of the (tuple) result.
    pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        Err(Error::runtime(UNAVAILABLE))
    }

    /// Run with i32 inputs, i32 outputs (for the XOR kernel).
    pub fn run_i32(&self, _inputs: &[(&[i32], &[usize])]) -> Result<Vec<Vec<i32>>> {
        Err(Error::runtime(UNAVAILABLE))
    }
}

/// PJRT client + executable cache (stub: construction always fails, so
/// callers take their documented fallback path).
pub struct Runtime {
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at the default artifacts dir.
    pub fn cpu() -> Result<Self> {
        Self::with_dir(super::artifacts_dir())
    }

    /// Create a CPU PJRT client rooted at `dir`.
    pub fn with_dir<P: Into<PathBuf>>(dir: P) -> Result<Self> {
        let _ = Runtime { dir: dir.into() };
        Err(Error::runtime(UNAVAILABLE))
    }

    /// Artifacts directory this runtime reads from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Does the artifact file exist?
    pub fn has_artifact(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, _name: &str) -> Result<Rc<Executable>> {
        Err(Error::runtime(UNAVAILABLE))
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }
}
