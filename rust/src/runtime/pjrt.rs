//! Real PJRT runtime over the vendored `xla` crate. Compiled only with
//! the `pjrt` cargo feature (see `rust/Cargo.toml` for how to vendor
//! the dependency); the default build uses [`super::stub`] instead.

use crate::error::{Error, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

fn xerr(what: &str, e: impl std::fmt::Display) -> Error {
    Error::runtime(format!("{what}: {e}"))
}

/// A loaded, compiled executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact name.
    pub name: String,
}

impl Executable {
    /// Run with f32 input buffers of the given shapes; returns the
    /// flattened f32 outputs of the (tuple) result.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| xerr(&format!("reshape input to {dims:?}"), e))?;
            literals.push(lit);
        }
        let mut result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| xerr("execute", e))?[0][0]
            .to_literal_sync()
            .map_err(|e| xerr("to_literal_sync", e))?;
        let parts = result.decompose_tuple().map_err(|e| xerr("decompose_tuple", e))?;
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| xerr("to_vec<f32>", e)))
            .collect()
    }

    /// Run with i32 inputs, i32 outputs (for the XOR kernel).
    pub fn run_i32(&self, inputs: &[(&[i32], &[usize])]) -> Result<Vec<Vec<i32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| xerr(&format!("reshape input to {dims:?}"), e))?;
            literals.push(lit);
        }
        let mut result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| xerr("execute", e))?[0][0]
            .to_literal_sync()
            .map_err(|e| xerr("to_literal_sync", e))?;
        let tuple = result.decompose_tuple().map_err(|e| xerr("decompose_tuple", e))?;
        tuple
            .into_iter()
            .map(|l| l.to_vec::<i32>().map_err(|e| xerr("to_vec<i32>", e)))
            .collect()
    }
}

/// PJRT client + executable cache. `PjRtClient` is `Rc`-based (not
/// `Send`), so a `Runtime` lives on one thread; the coordinator runs a
/// dedicated PJRT service thread and ships batches to it over channels
/// (see [`crate::coordinator`]).
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at the default artifacts dir.
    pub fn cpu() -> Result<Self> {
        Self::with_dir(super::artifacts_dir())
    }

    /// Create a CPU PJRT client rooted at `dir`.
    pub fn with_dir<P: Into<PathBuf>>(dir: P) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| xerr("create PJRT CPU client", e))?;
        Ok(Runtime { client, dir: dir.into(), cache: RefCell::new(HashMap::new()) })
    }

    /// Artifacts directory this runtime reads from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Does the artifact file exist?
    pub fn has_artifact(&self, name: &str) -> bool {
        self.path_of(name).exists()
    }

    fn path_of(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.path_of(name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| xerr(&format!("load HLO text {}", path.display()), e))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe =
            self.client.compile(&comp).map_err(|e| xerr(&format!("compile {name}"), e))?;
        let rc = Rc::new(Executable { exe, name: name.to_string() });
        self.cache.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
