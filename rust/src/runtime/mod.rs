//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The Python side (`python/compile/aot.py`) runs **once** at build time
//! (`make artifacts`) and lowers each L2 graph — which embeds the L1
//! Pallas kernels — to HLO *text* under `artifacts/`. With the `pjrt`
//! cargo feature enabled (which requires vendoring the `xla` crate —
//! see `rust/Cargo.toml`), this module wraps the PJRT CPU client to
//! load those files, compile them once, and execute them from the Rust
//! hot path; Python is never on the request path.
//!
//! Without the feature (the default in this offline environment) the
//! same API is a stub whose constructors return
//! [`Error::Runtime`](crate::Error::Runtime); the coordinator then
//! falls back to the pure-Rust cost mirror in [`crate::sram`], and the
//! PJRT integration tests skip. Either way the artifact bookkeeping
//! ([`artifacts_dir`], [`names`], [`missing_artifacts`]) works.
//!
//! Interchange is HLO text (not serialized `HloModuleProto`): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).

use std::path::{Path, PathBuf};

/// Default artifacts directory (overridable with `AMM_DSE_ARTIFACTS`).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("AMM_DSE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Names of the artifacts `aot.py` produces.
pub mod names {
    /// Batched SRAM cost model (the DSE hot path).
    pub const COST_MODEL: &str = "cost_model";
    /// XOR-bank parity reconstruction (H-NTX-Rd read path).
    pub const XOR_RECON: &str = "xor_recon";
    /// Tiled GEMM workload datapath.
    pub const GEMM: &str = "gemm";
    /// 3×3 stencil workload datapath.
    pub const STENCIL2D: &str = "stencil2d";
    /// Strided-FFT stage datapath.
    pub const FFT_STAGE: &str = "fft_stage";
    /// All artifact names.
    pub const ALL: [&str; 5] = [COST_MODEL, XOR_RECON, GEMM, STENCIL2D, FFT_STAGE];
}

/// Check whether all artifacts exist; returns the missing names.
/// Callers degrade gracefully (pure-Rust cost model) when non-empty.
pub fn missing_artifacts(dir: &Path) -> Vec<&'static str> {
    names::ALL
        .iter()
        .filter(|n| !dir.join(format!("{n}.hlo.txt")).exists())
        .copied()
        .collect()
}

/// Stable content fingerprint of one artifact's HLO text: FNV-1a
/// (64-bit) over the file bytes, `None` when the artifact is absent.
/// The cost subsystem keys persisted PJRT-scored rows to this value, so
/// rebuilding the cost model invalidates every previously stored row
/// instead of silently serving numbers from a different artifact.
pub fn artifact_fingerprint(dir: &Path, name: &str) -> Option<u64> {
    use crate::util::hash::{fnv1a, FNV_OFFSET};
    let bytes = std::fs::read(dir.join(format!("{name}.hlo.txt"))).ok()?;
    Some(fnv1a(FNV_OFFSET, &bytes))
}

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Executable, Runtime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_env_override() {
        // Don't mutate the env in-process (tests run in parallel);
        // just exercise the default path shape.
        let d = artifacts_dir();
        assert!(d.as_os_str().len() > 0);
    }

    #[test]
    fn missing_artifacts_lists_all_for_empty_dir() {
        let tmp = std::env::temp_dir().join("amm_dse_no_artifacts");
        let _ = std::fs::create_dir_all(&tmp);
        let missing = missing_artifacts(&tmp);
        assert_eq!(missing.len(), names::ALL.len());
    }

    #[test]
    fn artifact_fingerprint_tracks_content_and_absence() {
        let tmp = std::env::temp_dir().join("amm_dse_artifact_fp");
        let _ = std::fs::create_dir_all(&tmp);
        let file = tmp.join("cost_model.hlo.txt");
        let _ = std::fs::remove_file(&file);
        assert_eq!(artifact_fingerprint(&tmp, "cost_model"), None);
        std::fs::write(&file, "HloModule a").unwrap();
        let a = artifact_fingerprint(&tmp, "cost_model").unwrap();
        assert_eq!(artifact_fingerprint(&tmp, "cost_model"), Some(a), "deterministic");
        std::fs::write(&file, "HloModule b").unwrap();
        assert_ne!(artifact_fingerprint(&tmp, "cost_model"), Some(a));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_pjrt_unavailable() {
        let err = Runtime::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    // Compile/execute paths are covered by rust/tests/pjrt_cost.rs,
    // which skips when `make artifacts` has not run.
}
