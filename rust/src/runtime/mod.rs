//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The Python side (`python/compile/aot.py`) runs **once** at build time
//! (`make artifacts`) and lowers each L2 graph — which embeds the L1
//! Pallas kernels — to HLO *text* under `artifacts/`. This module wraps
//! the `xla` crate's PJRT CPU client to load those files, compile them
//! once, and execute them from the Rust hot path. Python is never on the
//! request path.
//!
//! Interchange is HLO text (not serialized `HloModuleProto`): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).

use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Default artifacts directory (overridable with `AMM_DSE_ARTIFACTS`).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("AMM_DSE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Names of the artifacts `aot.py` produces.
pub mod names {
    /// Batched SRAM cost model (the DSE hot path).
    pub const COST_MODEL: &str = "cost_model";
    /// XOR-bank parity reconstruction (H-NTX-Rd read path).
    pub const XOR_RECON: &str = "xor_recon";
    /// Tiled GEMM workload datapath.
    pub const GEMM: &str = "gemm";
    /// 3×3 stencil workload datapath.
    pub const STENCIL2D: &str = "stencil2d";
    /// Strided-FFT stage datapath.
    pub const FFT_STAGE: &str = "fft_stage";
    /// All artifact names.
    pub const ALL: [&str; 5] = [COST_MODEL, XOR_RECON, GEMM, STENCIL2D, FFT_STAGE];
}

/// A loaded, compiled executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact name.
    pub name: String,
}

impl Executable {
    /// Run with f32 input buffers of the given shapes; returns the
    /// flattened f32 outputs of the (tuple) result.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .with_context(|| format!("reshape input to {dims:?}"))?;
            literals.push(lit);
        }
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.decompose_tuple()?;
        parts.into_iter().map(|l| Ok(l.to_vec::<f32>()?)).collect()
    }

    /// Run with i32 inputs, i32 outputs (for the XOR kernel).
    pub fn run_i32(&self, inputs: &[(&[i32], &[usize])]) -> Result<Vec<Vec<i32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data).reshape(&dims)?;
            literals.push(lit);
        }
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let tuple = result.decompose_tuple()?;
        tuple.into_iter().map(|l| Ok(l.to_vec::<i32>()?)).collect()
    }
}

/// PJRT client + executable cache. `PjRtClient` is `Rc`-based (not
/// `Send`), so a `Runtime` lives on one thread; the coordinator runs a
/// dedicated PJRT service thread and ships batches to it over channels
/// (see [`crate::coordinator`]).
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at the default artifacts dir.
    pub fn cpu() -> Result<Self> {
        Self::with_dir(artifacts_dir())
    }

    /// Create a CPU PJRT client rooted at `dir`.
    pub fn with_dir<P: Into<PathBuf>>(dir: P) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, dir: dir.into(), cache: RefCell::new(HashMap::new()) })
    }

    /// Artifacts directory this runtime reads from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Does the artifact file exist?
    pub fn has_artifact(&self, name: &str) -> bool {
        self.path_of(name).exists()
    }

    fn path_of(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.path_of(name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("load HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
        let rc = Rc::new(Executable { exe, name: name.to_string() });
        self.cache.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// Check whether all artifacts exist; returns the missing names.
/// Callers degrade gracefully (pure-Rust cost model) when non-empty.
pub fn missing_artifacts(dir: &Path) -> Vec<&'static str> {
    names::ALL
        .iter()
        .filter(|n| !dir.join(format!("{n}.hlo.txt")).exists())
        .copied()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_env_override() {
        // Don't mutate the env in-process (tests run in parallel);
        // just exercise the default path shape.
        let d = artifacts_dir();
        assert!(d.as_os_str().len() > 0);
    }

    #[test]
    fn missing_artifacts_lists_all_for_empty_dir() {
        let tmp = std::env::temp_dir().join("amm_dse_no_artifacts");
        let _ = std::fs::create_dir_all(&tmp);
        let missing = missing_artifacts(&tmp);
        assert_eq!(missing.len(), names::ALL.len());
    }

    // Compile/execute paths are covered by rust/tests/pjrt_cost.rs,
    // which skips when `make artifacts` has not run.
}
