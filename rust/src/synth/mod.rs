//! DC-lite: gate-level analytical model of the AMM read/write-path logic.
//!
//! The paper synthesizes the XOR/LVT glue logic in Verilog with Synopsys
//! Design Compiler at UMC 45 nm (§III-A) and combines it with CACTI SRAM
//! numbers. We stand in for DC with a NAND2-equivalent gate model: each
//! logic structure (XOR reduction tree, mux tree, decoder, live-value
//! table register file) is expressed as a gate count + logic depth, and
//! converted to area/energy/delay with 45 nm standard-cell constants.
//! The aggregate numbers feed [`crate::mem`]'s cost composition exactly
//! the way the paper's synthesis tables feed Mem-Aladdin.

/// 45 nm standard-cell calibration.
pub mod cal {
    /// NAND2-equivalent gate area, µm² (typical 45 nm stdcell ~ 0.8 µm²
    /// for NAND2X1 plus routing overhead folded in).
    pub const GATE_UM2: f32 = 1.06;
    /// Switching energy per gate-equivalent toggle, pJ.
    pub const GATE_E_PJ: f32 = 0.0011;
    /// Gate delay (FO4-ish), ns.
    pub const GATE_D_NS: f32 = 0.022;
    /// Leakage per gate-equivalent, µW.
    pub const GATE_LEAK_UW: f32 = 0.0018;
    /// D-flip-flop cost in gate equivalents.
    pub const FF_GE: f32 = 6.0;
    /// XOR2 cost in gate equivalents.
    pub const XOR2_GE: f32 = 2.5;
    /// MUX2 cost in gate equivalents.
    pub const MUX2_GE: f32 = 1.8;
    /// Activity factor applied to dynamic energy (not every gate toggles
    /// every access).
    pub const ACTIVITY: f32 = 0.35;
}

/// A block of synthesized logic: cumulative gate-equivalents and the
/// critical-path depth in gate delays.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Logic {
    /// Total NAND2-equivalent gates.
    pub gates: f32,
    /// Critical path, in gate delays.
    pub depth: f32,
}

impl Logic {
    /// Parallel composition: areas add, critical path is the max.
    pub fn beside(self, other: Logic) -> Logic {
        Logic { gates: self.gates + other.gates, depth: self.depth.max(other.depth) }
    }
    /// Series composition: areas add, critical paths add.
    pub fn then(self, other: Logic) -> Logic {
        Logic { gates: self.gates + other.gates, depth: self.depth + other.depth }
    }
    /// Scale the block `n` times in parallel (e.g. per output port).
    pub fn times(self, n: f32) -> Logic {
        Logic { gates: self.gates * n, depth: self.depth }
    }

    /// Convert to physical cost.
    pub fn cost(self) -> LogicCost {
        LogicCost {
            area_um2: self.gates * cal::GATE_UM2,
            e_access_pj: self.gates * cal::GATE_E_PJ * cal::ACTIVITY,
            leak_uw: self.gates * cal::GATE_LEAK_UW,
            delay_ns: self.depth * cal::GATE_D_NS,
        }
    }
}

/// Physical cost of a logic block.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LogicCost {
    /// Standard-cell area, µm².
    pub area_um2: f32,
    /// Dynamic energy per access through the block, pJ.
    pub e_access_pj: f32,
    /// Leakage, µW.
    pub leak_uw: f32,
    /// Combinational delay, ns.
    pub delay_ns: f32,
}

/// `n`-input XOR reduction over a `width`-bit word: `(n−1)·width` XOR2
/// gates, `ceil(log2 n)` levels deep. This is the H-NTX read-reconstruct
/// path.
pub fn xor_tree(inputs: u32, width: u32) -> Logic {
    if inputs <= 1 {
        return Logic::default();
    }
    let n = inputs as f32;
    let w = width as f32;
    Logic {
        gates: (n - 1.0) * w * cal::XOR2_GE,
        depth: (inputs as f32).log2().ceil() * 1.4, // XOR2 ≈ 1.4 NAND2 delays
    }
}

/// `n`-to-1 one-hot mux over a `width`-bit word: `(n−1)·width` MUX2s in a
/// tree of depth `ceil(log2 n)`.
pub fn mux_tree(inputs: u32, width: u32) -> Logic {
    if inputs <= 1 {
        return Logic::default();
    }
    let n = inputs as f32;
    let w = width as f32;
    Logic {
        gates: (n - 1.0) * w * cal::MUX2_GE,
        depth: (inputs as f32).log2().ceil(),
    }
}

/// Address decoder for `depth` words: ~`depth/4` gate equivalents with
/// `log2(depth)` logic levels (pre-decode + word-line AND).
pub fn decoder(depth: u32) -> Logic {
    if depth <= 1 {
        return Logic::default();
    }
    Logic { gates: depth as f32 / 4.0, depth: (depth as f32).log2().ceil() * 0.5 }
}

/// A register file of `entries × bits` flip-flops plus write decoding and
/// a read mux per read port — the Live-Value Table of the LVT design.
pub fn register_table(entries: u32, bits: u32, read_ports: u32, write_ports: u32) -> Logic {
    let ff = Logic { gates: entries as f32 * bits as f32 * cal::FF_GE, depth: 1.0 };
    let wr = decoder(entries).times(write_ports as f32);
    let rd = mux_tree(entries, bits).times(read_ports as f32);
    ff.beside(wr).then(rd)
}

/// Bank-conflict comparator network for `ports` addresses of `addr_bits`
/// bits: pairwise compare = C(ports,2) comparators, each `addr_bits` XNORs
/// plus an AND tree.
pub fn conflict_comparators(ports: u32, addr_bits: u32) -> Logic {
    if ports <= 1 {
        return Logic::default();
    }
    let pairs = (ports * (ports - 1) / 2) as f32;
    Logic {
        gates: pairs * (addr_bits as f32 * 1.5 + addr_bits as f32 / 2.0),
        depth: (addr_bits as f32).log2().ceil() + 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xor_tree_counts() {
        let l = xor_tree(2, 32);
        assert_eq!(l.gates, 32.0 * cal::XOR2_GE);
        let l4 = xor_tree(4, 32);
        assert_eq!(l4.gates, 3.0 * 32.0 * cal::XOR2_GE);
        assert!(l4.depth > l.depth);
    }

    #[test]
    fn degenerate_trees_are_free() {
        assert_eq!(xor_tree(1, 64), Logic::default());
        assert_eq!(mux_tree(0, 64), Logic::default());
        assert_eq!(decoder(1), Logic::default());
    }

    #[test]
    fn composition_laws() {
        let a = Logic { gates: 10.0, depth: 2.0 };
        let b = Logic { gates: 5.0, depth: 3.0 };
        assert_eq!(a.beside(b), Logic { gates: 15.0, depth: 3.0 });
        assert_eq!(a.then(b), Logic { gates: 15.0, depth: 5.0 });
        assert_eq!(a.times(3.0).gates, 30.0);
    }

    #[test]
    fn lvt_grows_with_entries_and_ports() {
        let small = register_table(64, 1, 2, 2).cost();
        let big = register_table(1024, 2, 2, 2).cost();
        let wide = register_table(64, 1, 8, 4).cost();
        assert!(big.area_um2 > small.area_um2);
        assert!(wide.area_um2 > small.area_um2);
    }

    #[test]
    fn lvt_is_much_smaller_than_equivalent_sram_array() {
        // LVT stores log2(banks) bits per word — must be far below data.
        let lvt = register_table(1024, 2, 2, 2).cost();
        let data = crate::sram::macro_cost(crate::sram::MacroCfg::rw1(1024, 32));
        assert!(lvt.area_um2 < 4.0 * data.area_um2);
    }

    #[test]
    fn cost_conversion_is_linear_in_gates() {
        let l = Logic { gates: 100.0, depth: 4.0 };
        let c = l.cost();
        assert!((c.area_um2 - 100.0 * cal::GATE_UM2).abs() < 1e-4);
        assert!((c.delay_ns - 4.0 * cal::GATE_D_NS).abs() < 1e-6);
    }
}
