//! Figure/table emitters: CSV series + ASCII scatter plots for every
//! paper artifact (Fig 2, Fig 4 a–d, Fig 5, the §III-A synthesis table).

use crate::dse::{BenchSummary, DesignPoint};
use std::fmt::Write as _;
use std::io;
use std::path::Path;

/// Write the Fig-4 CSV for one benchmark: one row per design point with
/// the columns the paper plots (cycles, time, area, power) plus the
/// AMM/banking split.
pub fn fig4_csv(points: &[DesignPoint]) -> String {
    let mut s = String::from(
        "id,mem,is_amm,unroll,word_bytes,alus,cycles,period_ns,time_ns,area_um2,power_mw,port_stalls\n",
    );
    for p in points {
        let _ = writeln!(
            s,
            "{},{},{},{},{},{},{},{:.4},{:.1},{:.1},{:.4},{}",
            p.id,
            p.mem_id,
            p.is_amm as u8,
            p.unroll,
            p.word_bytes,
            p.alus,
            p.out.cycles,
            p.out.period_ns,
            p.out.time_ns,
            p.out.area_um2,
            p.out.power_mw,
            p.out.port_stalls
        );
    }
    s
}

/// Write the Fig-5 CSV: locality + performance ratio per benchmark.
///
/// Benchmarks outside the DSE set carry no sweep results: their best
/// times are `NaN` (or `±inf` from an empty family) and their ratio is
/// `None`. Those render as *empty* CSV fields — not the literal `NaN`
/// that used to leak into the file and choke downstream plotters.
pub fn fig5_csv(summaries: &[BenchSummary]) -> String {
    let mut s = String::from(
        "benchmark,spatial_locality,perf_ratio,best_banking_ns,best_amm_ns,n_points\n",
    );
    for b in summaries {
        let _ = writeln!(
            s,
            "{},{:.4},{},{},{},{}",
            b.name,
            b.locality,
            b.perf_ratio.map(|r| format!("{r:.4}")).unwrap_or_default(),
            ns_field(b.best_banking_ns),
            ns_field(b.best_amm_ns),
            b.n_points
        );
    }
    s
}

/// Frontier-only Fig-4 CSV: the (time, area) Pareto-optimal subset of
/// `points`, in frontier (time-ascending) order, same columns as
/// [`fig4_csv`]. `repro merge` emits this next to the full per-benchmark
/// CSV so a merged campaign's headline designs are one file.
pub fn pareto_csv(points: &[DesignPoint]) -> String {
    let front = crate::dse::pareto_front(points, |p| p.time_ns(), |p| p.area());
    let selected: Vec<DesignPoint> = front.into_iter().map(|i| points[i].clone()).collect();
    fig4_csv(&selected)
}

/// A best-time CSV field: fixed-point when finite, empty otherwise.
fn ns_field(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.1}")
    } else {
        String::new()
    }
}

/// ASCII scatter of (x=time, y=area or power), AMM points `o`, banking
/// `x` — the terminal rendition of a Fig-4 panel. Log-scaled axes.
pub fn ascii_scatter(
    points: &[DesignPoint],
    y_of: impl Fn(&DesignPoint) -> f64,
    title: &str,
    width: usize,
    height: usize,
) -> String {
    if points.is_empty() {
        return format!("{title}: (no points)\n");
    }
    let xs: Vec<f64> = points.iter().map(|p| p.time_ns().log10()).collect();
    let ys: Vec<f64> = points.iter().map(|p| y_of(p).log10()).collect();
    let (x0, x1) = min_max(&xs);
    let (y0, y1) = min_max(&ys);
    let mut grid = vec![vec![b' '; width]; height];
    for (i, p) in points.iter().enumerate() {
        let cx = scale(xs[i], x0, x1, width - 1);
        let cy = height - 1 - scale(ys[i], y0, y1, height - 1);
        let ch = if p.is_amm { b'o' } else { b'x' };
        // AMM wins ties so the blue points stay visible, as in Fig 4.
        if grid[cy][cx] != b'o' {
            grid[cy][cx] = ch;
        }
    }
    let mut s = format!(
        "{title}  [x: log10(time ns) {x0:.2}..{x1:.2}] [y: {y0:.2}..{y1:.2}]  o=AMM x=banking\n"
    );
    for row in grid {
        s.push_str(std::str::from_utf8(&row).unwrap());
        s.push('\n');
    }
    s
}

fn min_max(v: &[f64]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in v {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if (hi - lo).abs() < 1e-12 {
        hi = lo + 1.0;
    }
    (lo, hi)
}

fn scale(x: f64, lo: f64, hi: f64, max: usize) -> usize {
    (((x - lo) / (hi - lo)) * max as f64).round().clamp(0.0, max as f64) as usize
}

/// ASCII bar chart for Fig 5 (locality and ratio side by side, best
/// banking/AMM times on the right). Values a benchmark doesn't have —
/// no ratio, non-finite best times for the locality-only rows — render
/// as `-`.
pub fn fig5_ascii(summaries: &[BenchSummary]) -> String {
    let mut s = String::from(
        "benchmark     L_spatial                            perf-ratio (banking area / AMM area)  best_bank_ns  best_amm_ns\n",
    );
    for b in summaries {
        let lbar = bar(b.locality, 1.0, 28);
        let (rtxt, rbar) = match b.perf_ratio {
            Some(r) => (format!("{r:5.2}"), bar(r, 2.0, 28)),
            None => ("    -".into(), String::new()),
        };
        let _ = writeln!(
            s,
            "{:<12} {:5.3} {lbar:<28} {rtxt} {rbar:<28} {:>12} {:>12}",
            b.name,
            b.locality,
            ns_col(b.best_banking_ns),
            ns_col(b.best_amm_ns)
        );
    }
    s
}

/// AMM benefit of one summary: fastest banked time / fastest AMM time
/// (> 1 means true multi-porting wins), `None` when either side has no
/// finite best (locality-only rows, or a sweep missing one family).
pub fn amm_benefit(b: &BenchSummary) -> Option<f64> {
    if b.best_banking_ns.is_finite() && b.best_amm_ns.is_finite() && b.best_amm_ns > 0.0 {
        Some(b.best_banking_ns / b.best_amm_ns)
    } else {
        None
    }
}

/// The locality-curve CSV: AMM benefit against measured locality, rows
/// sorted by locality ascending (ties by name) so the file reads as the
/// figure's x-axis. Rows without a computable benefit keep their
/// locality and render the benefit field empty, like [`fig5_csv`].
pub fn locality_csv(summaries: &[BenchSummary]) -> String {
    let mut s = String::from(
        "benchmark,spatial_locality,amm_benefit,best_banking_ns,best_amm_ns,n_points\n",
    );
    for b in sorted_by_locality(summaries) {
        let _ = writeln!(
            s,
            "{},{:.4},{},{},{},{}",
            b.name,
            b.locality,
            amm_benefit(b).map(|r| format!("{r:.4}")).unwrap_or_default(),
            ns_field(b.best_banking_ns),
            ns_field(b.best_amm_ns),
            b.n_points
        );
    }
    s
}

/// ASCII rendition of the locality curve: one bar per dial point, x-axis
/// ordered by measured locality, bar length = AMM benefit (the `|` tick
/// marks benefit 1.0 — parity between the best banked and best AMM
/// design).
pub fn locality_ascii(summaries: &[BenchSummary]) -> String {
    let width = summaries.iter().map(|b| b.name.len()).max().unwrap_or(9).max(9);
    let mut s = format!(
        "{:<width$} {:>9} {:>11}  benefit (| = parity at 1.0)\n",
        "benchmark", "L_spatial", "amm_benefit"
    );
    for b in sorted_by_locality(summaries) {
        let (txt, chart) = match amm_benefit(b) {
            Some(r) => (format!("{r:7.3}"), benefit_bar(r, 2.0, 28)),
            None => ("      -".into(), String::new()),
        };
        let _ = writeln!(s, "{:<width$} {:>9.4} {txt:>11}  {chart}", b.name, b.locality);
    }
    s
}

/// Spearman rank correlation between measured locality and AMM benefit
/// over the rows where the benefit is computable; `None` below 2 such
/// rows. The paper's thesis makes this negative on a dial sweep.
pub fn locality_benefit_spearman(summaries: &[BenchSummary]) -> Option<f64> {
    let pairs: Vec<(f64, f64)> =
        summaries.iter().filter_map(|b| amm_benefit(b).map(|r| (b.locality, r))).collect();
    if pairs.len() < 2 {
        return None;
    }
    let (xs, ys): (Vec<f64>, Vec<f64>) = pairs.into_iter().unzip();
    Some(crate::util::stats::spearman(&xs, &ys))
}

/// Locality-ascending view of the summaries (ties broken by name so the
/// ordering — and therefore the CSV bytes — is total and stable).
fn sorted_by_locality(summaries: &[BenchSummary]) -> Vec<&BenchSummary> {
    let mut v: Vec<&BenchSummary> = summaries.iter().collect();
    v.sort_by(|a, b| {
        a.locality.total_cmp(&b.locality).then_with(|| a.name.cmp(&b.name))
    });
    v
}

/// A benefit bar with a parity tick: `#` up to the value, `|` at 1.0.
fn benefit_bar(v: f64, full: f64, width: usize) -> String {
    let mut bar: Vec<u8> = bar(v, full, width).into_bytes();
    bar.resize(width, b' ');
    let tick = ((1.0 / full) * width as f64).round() as usize;
    if tick < width && bar[tick] != b'#' {
        bar[tick] = b'|';
    }
    String::from_utf8(bar).unwrap()
}

/// A best-time ASCII column: fixed-point when finite, `-` otherwise.
fn ns_col(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.1}")
    } else {
        "-".into()
    }
}

fn bar(v: f64, full: f64, width: usize) -> String {
    let n = ((v / full) * width as f64).round().clamp(0.0, width as f64) as usize;
    "#".repeat(n)
}

/// Write a string to `path`, creating parent dirs.
pub fn write_file(path: &Path, contents: &str) -> io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, contents)
}

/// Markdown table of paper-vs-measured rows (EXPERIMENTS.md helper).
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "| {} |", headers.join(" | "));
    let _ = writeln!(s, "|{}|", headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for r in rows {
        let _ = writeln!(s, "| {} |", r.join(" | "));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::DesignPoint;
    use crate::sched::SimOutput;

    fn pt(id: &str, amm: bool, time: f64, area: f32) -> DesignPoint {
        DesignPoint {
            id: id.into(),
            mem_id: id.into(),
            is_amm: amm,
            unroll: 1,
            word_bytes: 8,
            alus: 2,
            out: SimOutput {
                time_ns: time,
                area_um2: area,
                cycles: time as u64,
                power_mw: 1.0,
                ..Default::default()
            },
        }
    }

    #[test]
    fn csv_has_header_and_rows() {
        let points = vec![pt("a", false, 100.0, 5000.0), pt("b", true, 50.0, 8000.0)];
        let csv = fig4_csv(&points);
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.lines().nth(2).unwrap().starts_with("b,b,1,"));
    }

    #[test]
    fn scatter_renders_both_markers() {
        let points = vec![pt("a", false, 100.0, 5000.0), pt("b", true, 50.0, 8000.0)];
        let s = ascii_scatter(&points, |p| p.area(), "test", 40, 10);
        assert!(s.contains('o'));
        assert!(s.contains('x'));
    }

    #[test]
    fn empty_scatter_ok() {
        let s = ascii_scatter(&[], |p| p.area(), "empty", 40, 10);
        assert!(s.contains("no points"));
    }

    #[test]
    fn fig5_renders_missing_values_as_empty_or_dash() {
        // A locality-only row (no sweep): NaN bests, no ratio.
        let rows = vec![
            BenchSummary {
                name: "aes".into(),
                locality: 0.9,
                perf_ratio: None,
                best_banking_ns: f64::NAN,
                best_amm_ns: f64::INFINITY,
                n_points: 0,
            },
            BenchSummary {
                name: "gemm".into(),
                locality: 0.1,
                perf_ratio: Some(1.25),
                best_banking_ns: 120.0,
                best_amm_ns: 80.0,
                n_points: 8,
            },
        ];
        let csv = fig5_csv(&rows);
        let aes = csv.lines().nth(1).unwrap();
        assert_eq!(aes, "aes,0.9000,,,,0", "NaN/inf must become empty fields, not NaN text");
        assert!(!csv.contains("NaN"), "{csv}");
        let gemm = csv.lines().nth(2).unwrap();
        assert!(gemm.starts_with("gemm,0.1000,1.2500,120.0,80.0,8"), "{gemm}");
        let ascii = fig5_ascii(&rows);
        let aes_line = ascii.lines().find(|l| l.starts_with("aes")).unwrap();
        assert!(aes_line.trim_end().ends_with('-'), "{aes_line:?}");
        assert!(!ascii.contains("NaN"), "{ascii}");
    }

    fn summary(name: &str, locality: f64, bank: f64, amm: f64) -> BenchSummary {
        BenchSummary {
            name: name.into(),
            locality,
            perf_ratio: None,
            best_banking_ns: bank,
            best_amm_ns: amm,
            n_points: 4,
        }
    }

    #[test]
    fn locality_csv_sorts_by_locality_and_blanks_missing_benefit() {
        let rows = vec![
            summary("synth:conflict=0", 0.25, 100.0, 100.0),
            summary("synth:conflict=0.9", 0.05, 400.0, 110.0),
            summary("aes-locality-only", 0.9, f64::NAN, f64::INFINITY),
        ];
        let csv = locality_csv(&rows);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(
            lines[0],
            "benchmark,spatial_locality,amm_benefit,best_banking_ns,best_amm_ns,n_points"
        );
        // locality ascending: the high-conflict low-locality row first
        assert!(lines[1].starts_with("synth:conflict=0.9,0.0500,3.6364,"), "{}", lines[1]);
        assert!(lines[2].starts_with("synth:conflict=0,0.2500,1.0000,"), "{}", lines[2]);
        assert_eq!(lines[3], "aes-locality-only,0.9000,,,,4");
        assert!(!csv.contains("NaN"), "{csv}");
        // byte-stable: same input, same bytes
        assert_eq!(csv, locality_csv(&rows));
    }

    #[test]
    fn locality_ascii_marks_parity() {
        let rows =
            vec![summary("a", 0.3, 100.0, 50.0), summary("b", 0.1, 100.0, 100.0)];
        let s = locality_ascii(&rows);
        assert!(s.contains('#'));
        assert!(s.contains('|'), "parity tick expected: {s}");
        // b (locality 0.1) renders before a (0.3)
        let bi = s.find("\nb ").unwrap();
        let ai = s.find("\na ").unwrap();
        assert!(bi < ai, "{s}");
    }

    #[test]
    fn spearman_is_negative_on_an_anticorrelated_curve() {
        let rows = vec![
            summary("p0", 0.25, 100.0, 100.0),
            summary("p1", 0.20, 150.0, 100.0),
            summary("p2", 0.10, 250.0, 100.0),
            summary("p3", 0.05, 400.0, 100.0),
            summary("no-benefit", 0.5, f64::NAN, f64::NAN),
        ];
        let rho = locality_benefit_spearman(&rows).unwrap();
        assert!(rho < -0.99, "rho={rho}");
        assert_eq!(locality_benefit_spearman(&rows[..1]), None, "one point: no correlation");
    }

    #[test]
    fn markdown_table_shape() {
        let t = markdown_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(t.lines().count(), 3);
    }
}
